//! Pipeline DAGs: specs, topological planning, and contract composition.
//!
//! A [`PipelineSpec`] is the in-memory form of a "DAG code folder"
//! (paper Fig. 1): a set of typed nodes, each consuming one or more named
//! tables and producing exactly one (`Table(s) -> Table`, §3.3). Specs
//! come from the builder API or from the textual project format in
//! [`parser`].
//!
//! [`PipelineSpec::plan`] performs the control-plane half of fail-fast:
//! M1 local checks for every declared schema, cycle/unknown-reference
//! detection, then M2 boundary checks for every edge — and only then
//! emits an executable [`Plan`].

pub mod parser;

use std::collections::{BTreeMap, BTreeSet};

use crate::contracts::checker::{check_local, check_plan};
use crate::contracts::schema::SchemaRegistry;
use crate::error::{BauplanError, Result};

/// One node of a pipeline: consumes `inputs`, produces table `output`.
#[derive(Debug, Clone, PartialEq)]
pub struct NodeSpec {
    /// Output table name (unique per pipeline).
    pub output: String,
    /// Schema the output claims to satisfy.
    pub out_schema: String,
    /// (table name, schema the node expects for it).
    pub inputs: Vec<(String, String)>,
    /// AOT artifact implementing the node (`parent`, `child`, ...).
    pub op: String,
    /// Runtime f32 parameters fed to the artifact (lo/hi/scale/offset...).
    pub params: Vec<f32>,
}

impl NodeSpec {
    pub fn new(output: &str, out_schema: &str, op: &str) -> NodeSpec {
        NodeSpec {
            output: output.into(),
            out_schema: out_schema.into(),
            inputs: Vec::new(),
            op: op.into(),
            params: Vec::new(),
        }
    }

    pub fn input(mut self, table: &str, schema: &str) -> NodeSpec {
        self.inputs.push((table.into(), schema.into()));
        self
    }

    pub fn with_params(mut self, params: Vec<f32>) -> NodeSpec {
        self.params = params;
        self
    }
}

/// A whole pipeline: schemas + nodes + the source tables it reads.
#[derive(Debug, Clone, Default)]
pub struct PipelineSpec {
    pub name: String,
    pub registry: SchemaRegistry,
    pub nodes: Vec<NodeSpec>,
    /// Tables read from the lake (not produced by any node), with the
    /// schema the pipeline expects them to satisfy.
    pub sources: BTreeMap<String, String>,
}

/// An executable plan: nodes in dependency order, contracts verified.
#[derive(Debug, Clone)]
pub struct Plan {
    pub pipeline: String,
    /// Topologically ordered node indices into `nodes`.
    pub nodes: Vec<NodeSpec>,
    /// Static cache fingerprint per node, aligned with `nodes` — the
    /// plan-time half of the run-cache key (op + parameter bits + the
    /// contract fingerprints on both sides of the boundary; see
    /// [`crate::cache::key`]). Derived from content only, so it is
    /// deterministic across processes and insensitive to the order
    /// nodes were declared in.
    pub node_fps: Vec<String>,
    /// Explicit dependency edges, aligned with `nodes`: `deps[i]` holds
    /// the indices of the producer nodes whose outputs node `i` reads
    /// (sorted, deduplicated; source tables contribute no edge). Because
    /// `nodes` is topologically ordered, every entry of `deps[i]` is
    /// `< i` — the wavefront scheduler's ready-set computation
    /// ([`Plan::levels`], [`Plan::dependents`]) relies on this.
    pub deps: Vec<Vec<usize>>,
    pub sources: BTreeMap<String, String>,
}

impl PipelineSpec {
    pub fn new(name: &str, registry: SchemaRegistry) -> PipelineSpec {
        PipelineSpec {
            name: name.into(),
            registry,
            nodes: Vec::new(),
            sources: BTreeMap::new(),
        }
    }

    pub fn source(mut self, table: &str, schema: &str) -> PipelineSpec {
        self.sources.insert(table.into(), schema.into());
        self
    }

    pub fn node(mut self, node: NodeSpec) -> PipelineSpec {
        self.nodes.push(node);
        self
    }

    /// The paper's running-example pipeline over the paper schemas:
    /// `raw_table -> parent_table -> child_table -> grand_child`.
    pub fn paper_pipeline() -> PipelineSpec {
        PipelineSpec::new("paper_dag", SchemaRegistry::with_paper_schemas())
            .source("raw_table", "RawSchema")
            .node(
                NodeSpec::new("parent_table", "ParentSchema", "parent")
                    .input("raw_table", "RawSchema"),
            )
            .node(
                NodeSpec::new("child_table", "ChildSchema", "child")
                    .input("parent_table", "ParentSchema")
                    .with_params(vec![0.0, 1e6, 0.5, 1.0]),
            )
            .node(
                NodeSpec::new("grand_child", "Grand", "grand_child")
                    .input("child_table", "ChildSchema")
                    .with_params(vec![-1e9, 1e9, 1.0, 0.0]),
            )
    }

    /// Validate and order the DAG — moments M1 and M2.
    pub fn plan(&self) -> Result<Plan> {
        // -- structural checks -------------------------------------------
        let mut producers: BTreeMap<&str, usize> = BTreeMap::new();
        for (i, n) in self.nodes.iter().enumerate() {
            if self.sources.contains_key(&n.output) {
                return Err(BauplanError::Dag(format!(
                    "node '{}' shadows a source table",
                    n.output
                )));
            }
            if producers.insert(&n.output, i).is_some() {
                return Err(BauplanError::Dag(format!("two nodes produce table '{}'", n.output)));
            }
        }
        for n in &self.nodes {
            for (t, _) in &n.inputs {
                if !self.sources.contains_key(t) && !producers.contains_key(t.as_str()) {
                    return Err(BauplanError::Dag(format!(
                        "node '{}' reads unknown table '{t}'",
                        n.output
                    )));
                }
            }
        }

        // -- M1: every schema mentioned must locally typecheck ------------
        let mut schemas_used = BTreeSet::new();
        for n in &self.nodes {
            schemas_used.insert(n.out_schema.clone());
            for (_, s) in &n.inputs {
                schemas_used.insert(s.clone());
            }
        }
        for s in self.sources.values() {
            schemas_used.insert(s.clone());
        }
        for s in &schemas_used {
            let schema = self.registry.get(s)?;
            check_local(schema, &self.registry)?;
        }

        // -- topological order (Kahn) -------------------------------------
        let n = self.nodes.len();
        let mut indegree = vec![0usize; n];
        let mut dependents: Vec<Vec<usize>> = vec![Vec::new(); n];
        for (i, node) in self.nodes.iter().enumerate() {
            for (t, _) in &node.inputs {
                if let Some(&p) = producers.get(t.as_str()) {
                    indegree[i] += 1;
                    dependents[p].push(i);
                }
            }
        }
        let mut queue: Vec<usize> = (0..n).filter(|&i| indegree[i] == 0).collect();
        // Deterministic order: smallest index first.
        queue.sort_unstable();
        let mut order = Vec::with_capacity(n);
        while let Some(i) = queue.pop() {
            order.push(i);
            for &d in &dependents[i] {
                indegree[d] -= 1;
                if indegree[d] == 0 {
                    queue.push(d);
                    queue.sort_unstable();
                }
            }
        }
        if order.len() != n {
            return Err(BauplanError::Dag("pipeline contains a cycle".into()));
        }

        // -- M2: every edge must compose ------------------------------------
        for node in &self.nodes {
            for (t, expected_schema) in &node.inputs {
                let upstream_schema_name = if let Some(&p) = producers.get(t.as_str()) {
                    self.nodes[p].out_schema.clone()
                } else {
                    self.sources[t].clone()
                };
                if &upstream_schema_name != expected_schema {
                    return Err(BauplanError::ContractPlan(format!(
                        "node '{}' expects table '{t}' as {expected_schema}, \
                         but upstream produces {upstream_schema_name}",
                        node.output)));
                }
                let up = self.registry.get(&upstream_schema_name)?;
                let down_out = self.registry.get(&node.out_schema)?;
                check_plan(up, down_out)?;
            }
        }

        // -- per-node cache fingerprints (plan-time half of the run-cache
        //    key): content-only, so declaration order cannot leak in -----
        let nodes: Vec<NodeSpec> = order.into_iter().map(|i| self.nodes[i].clone()).collect();
        let mut node_fps = Vec::with_capacity(nodes.len());
        for node in &nodes {
            let out_fp =
                crate::cache::key::contract_fingerprint(self.registry.get(&node.out_schema)?);
            let input_fps = node
                .inputs
                .iter()
                .map(|(_, s)| {
                    Ok(crate::cache::key::contract_fingerprint(self.registry.get(s)?))
                })
                .collect::<Result<Vec<String>>>()?;
            node_fps.push(crate::cache::key::node_static_fingerprint(
                &node.op,
                &node.params,
                &out_fp,
                &input_fps,
            ));
        }

        // -- dependency edges over the topological order (the wavefront
        //    scheduler's adjacency; producer index < consumer index) ----
        let deps: Vec<Vec<usize>> = {
            let topo_producers: BTreeMap<&str, usize> = nodes
                .iter()
                .enumerate()
                .map(|(i, n)| (n.output.as_str(), i))
                .collect();
            nodes
                .iter()
                .map(|n| {
                    let mut d: Vec<usize> = n
                        .inputs
                        .iter()
                        .filter_map(|(t, _)| topo_producers.get(t.as_str()).copied())
                        .collect();
                    d.sort_unstable();
                    d.dedup();
                    d
                })
                .collect()
        };

        Ok(Plan {
            pipeline: self.name.clone(),
            nodes,
            node_fps,
            deps,
            sources: self.sources.clone(),
        })
    }
}

impl Plan {
    /// Tables this plan writes, in execution order.
    pub fn outputs(&self) -> Vec<&str> {
        self.nodes.iter().map(|n| n.output.as_str()).collect()
    }

    /// Static cache fingerprint of the node producing `output`.
    pub fn node_fp(&self, output: &str) -> Option<&str> {
        self.nodes
            .iter()
            .position(|n| n.output == output)
            .map(|i| self.node_fps[i].as_str())
    }

    /// Inverse dependency edges: `dependents()[i]` lists the nodes that
    /// consume node `i`'s output (each sorted ascending). The wavefront
    /// scheduler walks these when a node finishes to discover newly
    /// ready work.
    pub fn dependents(&self) -> Vec<Vec<usize>> {
        let mut out = vec![Vec::new(); self.nodes.len()];
        for (i, deps) in self.deps.iter().enumerate() {
            for &d in deps {
                out[d].push(i);
            }
        }
        out
    }

    /// Wavefront levels: `levels()[k]` holds every node whose longest
    /// dependency chain has length `k` — all nodes in one level are
    /// mutually independent and can execute concurrently once every
    /// earlier level committed. `levels().len()` is the DAG's critical
    /// path length (the `run.wavefronts` metric).
    pub fn levels(&self) -> Vec<Vec<usize>> {
        if self.nodes.is_empty() {
            return Vec::new();
        }
        let mut level = vec![0usize; self.nodes.len()];
        let mut max_level = 0usize;
        for i in 0..self.nodes.len() {
            for &d in &self.deps[i] {
                // topological order: level[d] is already final
                level[i] = level[i].max(level[d] + 1);
            }
            max_level = max_level.max(level[i]);
        }
        let mut out = vec![Vec::new(); max_level + 1];
        for (i, &l) in level.iter().enumerate() {
            out[l].push(i);
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::contracts::schema::{Field, Schema};
    use crate::contracts::types::{FieldType, LogicalType};

    #[test]
    fn paper_pipeline_plans() {
        let plan = PipelineSpec::paper_pipeline().plan().unwrap();
        assert_eq!(plan.outputs(), vec!["parent_table", "child_table", "grand_child"]);
    }

    #[test]
    fn cycle_detected() {
        let spec = PipelineSpec::new("cyc", SchemaRegistry::with_paper_schemas())
            .node(
                NodeSpec::new("a", "ParentSchema", "noop").input("b", "ParentSchema"),
            )
            .node(
                NodeSpec::new("b", "ParentSchema", "noop").input("a", "ParentSchema"),
            );
        let err = spec.plan().unwrap_err();
        assert!(err.to_string().contains("cycle"));
    }

    #[test]
    fn unknown_input_detected() {
        let spec = PipelineSpec::new("bad", SchemaRegistry::with_paper_schemas()).node(
            NodeSpec::new("a", "ParentSchema", "noop").input("ghost", "RawSchema"),
        );
        assert!(spec.plan().is_err());
    }

    #[test]
    fn duplicate_producer_detected() {
        let spec = PipelineSpec::new("dup", SchemaRegistry::with_paper_schemas())
            .source("raw_table", "RawSchema")
            .node(NodeSpec::new("a", "ParentSchema", "op").input("raw_table", "RawSchema"))
            .node(NodeSpec::new("a", "ParentSchema", "op").input("raw_table", "RawSchema"));
        assert!(spec.plan().is_err());
    }

    #[test]
    fn schema_mismatch_at_boundary_is_m2() {
        // child expects parent_table as ParentSchema, but we declare the
        // node to output Grand instead.
        let spec = PipelineSpec::new("m2", SchemaRegistry::with_paper_schemas())
            .source("raw_table", "RawSchema")
            .node(
                NodeSpec::new("parent_table", "Grand", "parent")
                    .input("raw_table", "RawSchema"),
            )
            .node(
                NodeSpec::new("child_table", "ChildSchema", "child")
                    .input("parent_table", "ParentSchema"),
            );
        let err = spec.plan().unwrap_err();
        assert_eq!(err.contract_moment(), Some(2));
    }

    #[test]
    fn locally_broken_schema_is_m1() {
        let mut registry = SchemaRegistry::with_paper_schemas();
        registry
            .register(Schema::new("BadNarrow", vec![
                Field::new("col4", FieldType::new(LogicalType::Int))
                    .inherited("ChildSchema", "col4"), // narrowing, no cast
            ]))
            .unwrap();
        let spec = PipelineSpec::new("m1", registry)
            .source("raw_table", "RawSchema")
            .node(
                NodeSpec::new("t", "BadNarrow", "noop").input("raw_table", "RawSchema"),
            );
        let err = spec.plan().unwrap_err();
        assert_eq!(err.contract_moment(), Some(1));
    }

    #[test]
    fn diamond_topology_orders_correctly() {
        // raw -> a, raw -> b, (a, b) -> c
        let spec = PipelineSpec::new("diamond", SchemaRegistry::with_paper_schemas())
            .source("raw_table", "RawSchema")
            .node(NodeSpec::new("a", "ParentSchema", "parent").input("raw_table", "RawSchema"))
            .node(NodeSpec::new("b", "ParentSchema", "parent").input("raw_table", "RawSchema"))
            .node(
                NodeSpec::new("c", "ChildSchema", "child")
                    .input("a", "ParentSchema")
                    .input("b", "ParentSchema"),
            );
        let plan = spec.plan().unwrap();
        let pos = |t: &str| plan.outputs().iter().position(|&x| x == t).unwrap();
        assert!(pos("a") < pos("c"));
        assert!(pos("b") < pos("c"));
    }

    #[test]
    fn deps_levels_and_dependents_on_a_chain() {
        let plan = PipelineSpec::paper_pipeline().plan().unwrap();
        // linear chain: each node depends on exactly the previous one
        assert_eq!(plan.deps, vec![vec![], vec![0], vec![1]]);
        assert_eq!(plan.dependents(), vec![vec![1], vec![2], vec![]]);
        assert_eq!(plan.levels(), vec![vec![0], vec![1], vec![2]]);
    }

    #[test]
    fn deps_levels_and_dependents_on_a_diamond() {
        // raw -> a, raw -> b, (a, b) -> c: one 2-wide wavefront + join
        let spec = PipelineSpec::new("diamond", SchemaRegistry::with_paper_schemas())
            .source("raw_table", "RawSchema")
            .node(NodeSpec::new("a", "ParentSchema", "parent").input("raw_table", "RawSchema"))
            .node(NodeSpec::new("b", "ParentSchema", "parent").input("raw_table", "RawSchema"))
            .node(
                NodeSpec::new("c", "ChildSchema", "child")
                    .input("a", "ParentSchema")
                    .input("b", "ParentSchema"),
            );
        let plan = spec.plan().unwrap();
        let idx = |t: &str| plan.nodes.iter().position(|n| n.output == t).unwrap();
        let (a, b, c) = (idx("a"), idx("b"), idx("c"));
        assert!(plan.deps[a].is_empty());
        assert!(plan.deps[b].is_empty());
        assert_eq!(plan.deps[c], { let mut v = vec![a, b]; v.sort_unstable(); v });
        let levels = plan.levels();
        assert_eq!(levels.len(), 2, "diamond has two wavefronts");
        assert_eq!(levels[0].len(), 2);
        assert_eq!(levels[1], vec![c]);
        let dependents = plan.dependents();
        assert_eq!(dependents[a], vec![c]);
        assert_eq!(dependents[b], vec![c]);
        assert!(dependents[c].is_empty());
    }

    #[test]
    fn levels_empty_plan() {
        let spec = PipelineSpec::new("empty", SchemaRegistry::with_paper_schemas());
        let plan = spec.plan().unwrap();
        assert!(plan.levels().is_empty());
        assert!(plan.dependents().is_empty());
    }
}
