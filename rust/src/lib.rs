//! # bauplan — a correct-by-design lakehouse (reproduction)
//!
//! Reproduction of *Building a Correct-by-Design Lakehouse: Data Contracts,
//! Versioning, and Transactional Pipelines for Humans and Agents*
//! (CS.DC 2026). Three pipeline-level correctness mechanisms on top of a
//! storage substrate with atomic single-table snapshot evolution:
//!
//! 1. **Typed table contracts** ([`contracts`]) — interfaces between DAG
//!    nodes are explicit, machine-checkable schemas; violations fail at the
//!    earliest possible *moment* (local / plan / runtime).
//! 2. **Git-for-data** ([`catalog`], [`merge`]) — commits are immutable
//!    `table -> snapshot` maps with a parent relation; branches are movable
//!    refs; merges are zero-copy pointer operations. Ref evolution is
//!    durable: every mutation is written ahead to an append-only commit
//!    journal ([`catalog::journal`]), periodic checkpoints bound replay,
//!    and [`catalog::Catalog::recover`] rebuilds the exact pre-crash
//!    state. The write/recovery protocol is specified step by step in
//!    `doc/COMMIT_PIPELINE.md`, with each invariant mapped to the test
//!    that enforces it.
//! 3. **Transactional runs** ([`runs`]) — a pipeline executes on a hidden
//!    transactional branch and publishes atomically: readers of the target
//!    branch observe *all* outputs of a run or *none*.
//!
//! The compute layer is AOT-compiled XLA: jax/Pallas kernels are lowered at
//! build time to `artifacts/*.hlo.txt` and executed by [`runtime`] through
//! the PJRT C API. Python never runs on the request path. (The offline
//! build compiles against the stub PJRT shim in [`runtime::pjrt`]; swap
//! in the real `xla` crate to link the runtime — everything catalog-side
//! is independent of it.)
//!
//! [`model`] is a bounded model checker over the same abstractions as the
//! paper's Alloy spec; it reproduces the Figure-4 counterexample (aborted
//! transactional branches are forkable ⇒ global inconsistency) and shows
//! the visibility guardrail closes it.

// Style lints the codebase deliberately keeps out of CI's
// `clippy -D warnings` gate: the paper-shaped APIs (the commit path and
// kernel call sites) take many positional arguments by design, and the
// index-driven loops mirror the fixed-shape tensor code they feed.
#![allow(
    clippy::too_many_arguments,
    clippy::needless_range_loop,
    clippy::new_without_default,
    clippy::type_complexity,
    clippy::len_without_is_empty,
    clippy::collapsible_if,
    clippy::collapsible_else_if,
    clippy::manual_flatten,
    clippy::comparison_chain,
    clippy::large_enum_variant,
    clippy::result_large_err
)]

pub mod error;
pub mod util;
pub mod testing;
pub mod metrics;
pub mod trace;
pub mod bench_util;

pub mod storage;
pub mod catalog;
pub mod audit;
pub mod cache;
pub mod merge;
pub mod contracts;
pub mod dag;
pub mod runtime;
pub mod worker;
pub mod control_plane;
pub mod runs;
pub mod client;
pub mod server;
pub mod model;
pub mod sim;
pub mod data;
pub mod cli;

pub use error::{BauplanError, Result};
