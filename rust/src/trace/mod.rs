//! Structured tracing: explicit-propagation spans for runs and requests.
//!
//! The paper's transactional-run guarantee is only auditable if every
//! run leaves a causally-ordered record of what executed, what it read,
//! and what it published. This module is that record, zero-dep and
//! explicit by construction:
//!
//! - a [`TraceCtx`] (trace id + span id) is created per client call /
//!   per HTTP request and propagated **explicitly** — there are no
//!   thread-locals; spans are passed through the `Runner`, the
//!   wavefront scheduler, cache lookups, and the catalog commit paths
//!   as values;
//! - a [`Trace`] collects the spans of one run into a capped,
//!   truncation-counted buffer that is journaled with the terminal
//!   `RunState` (`JournalOp::RunTrace`), so `bauplan trace <run-id>`
//!   works across process restarts;
//! - [`flight::FlightRecorder`] is the second sink: a fixed-size ring
//!   buffer for non-run catalog/server operations, dumped to
//!   `<lake>/flight/` on catalog poisoning, failed recovery, or server
//!   shutdown;
//! - [`chrome::chrome_trace_events`] exports either sink's JSON as
//!   Chrome `trace_event` JSON for flamegraph viewing.
//!
//! `RemoteClient` propagates the context over the wire in the
//! [`TRACE_HEADER`] header (`<trace_id>/<span_id>`), parsed in
//! `server/http.rs` and attached in `server/api.rs`, so a loopback run
//! produces one stitched client → server → scheduler → journal trace.
//! Spec: `doc/OBSERVABILITY.md`.
#![warn(missing_docs)]

pub mod chrome;
pub mod flight;

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Instant;

use crate::util::json::Json;

pub use chrome::chrome_trace_events;
pub use flight::{FlightRecorder, FlightSpan, DEFAULT_FLIGHT_CAP, FLIGHT_DIR};

/// Wire header carrying the trace context: `x-bauplan-trace:
/// <trace_id>/<span_id>`.
pub const TRACE_HEADER: &str = "x-bauplan-trace";

/// Default per-trace span cap (see [`TraceConfig::max_spans`]).
pub const DEFAULT_MAX_SPANS: usize = 512;

/// A propagated trace context: which trace this work belongs to, and
/// which span is its parent. Created per client call / per HTTP
/// request; crosses the wire as [`TRACE_HEADER`].
#[derive(Debug, Clone, PartialEq)]
pub struct TraceCtx {
    /// The trace this work belongs to.
    pub trace_id: String,
    /// The caller's span — the parent of whatever the callee records.
    pub span_id: u64,
}

impl TraceCtx {
    /// Fresh context for a new client-originated call: a new trace id
    /// and span id 1 (the caller's implicit root span).
    pub fn new() -> TraceCtx {
        TraceCtx { trace_id: crate::util::id::unique_id("trace"), span_id: 1 }
    }

    /// The wire encoding (`<trace_id>/<span_id>`).
    pub fn header_value(&self) -> String {
        format!("{}/{}", self.trace_id, self.span_id)
    }

    /// Inverse of [`TraceCtx::header_value`]; `None` for malformed
    /// input (the server ignores bad headers rather than erroring).
    pub fn parse(s: &str) -> Option<TraceCtx> {
        let (trace_id, span) = s.split_once('/')?;
        if trace_id.is_empty() || trace_id.len() > 128 {
            return None;
        }
        let span_id: u64 = span.parse().ok()?;
        Some(TraceCtx { trace_id: trace_id.to_string(), span_id })
    }
}

/// Tracing knobs carried by the `Runner`.
#[derive(Debug, Clone)]
pub struct TraceConfig {
    /// `false` = every span is a no-op ([`Trace::disabled`]); the
    /// bench_trace overhead gate compares against exactly this.
    pub enabled: bool,
    /// Spans past this cap are dropped (counted in `truncated`), so a
    /// journaled run trace stays bounded.
    pub max_spans: usize,
}

impl Default for TraceConfig {
    fn default() -> TraceConfig {
        TraceConfig { enabled: true, max_spans: DEFAULT_MAX_SPANS }
    }
}

impl TraceConfig {
    /// Tracing off: spans cost one branch and no allocation.
    pub fn disabled() -> TraceConfig {
        TraceConfig { enabled: false, ..TraceConfig::default() }
    }
}

/// One finished span: name, interval, status, typed attributes.
#[derive(Debug, Clone, PartialEq)]
pub struct SpanRecord {
    /// Span id, unique within the trace; parents have smaller ids.
    pub id: u64,
    /// Parent span id (`None` for the trace root).
    pub parent: Option<u64>,
    /// Span name (taxonomy in `doc/OBSERVABILITY.md`).
    pub name: String,
    /// Start, µs wall clock (monotonic within the trace).
    pub start_us: u64,
    /// End, µs wall clock (`end_us >= start_us`).
    pub end_us: u64,
    /// `"ok"` or `"error"`.
    pub status: String,
    /// Typed key → value attributes (string / number / bool).
    pub attrs: Vec<(String, Json)>,
}

impl SpanRecord {
    /// Canonical-JSON encoding (one element of a trace's `spans`).
    pub fn to_json(&self) -> Json {
        let attrs: std::collections::BTreeMap<String, Json> =
            self.attrs.iter().cloned().collect();
        Json::obj(vec![
            ("id", Json::num(self.id as f64)),
            (
                "parent",
                match self.parent {
                    Some(p) => Json::num(p as f64),
                    None => Json::Null,
                },
            ),
            ("name", Json::str(&self.name)),
            ("start_us", Json::num(self.start_us as f64)),
            ("end_us", Json::num(self.end_us as f64)),
            ("status", Json::str(&self.status)),
            ("attrs", Json::Obj(attrs)),
        ])
    }
}

struct TraceInner {
    trace_id: String,
    /// Wire-propagated parent of the trace root (the caller's span id).
    origin: Option<u64>,
    epoch: Instant,
    epoch_wall_us: u64,
    next_id: AtomicU64,
    spans: Mutex<Vec<SpanRecord>>,
    max_spans: usize,
    truncated: AtomicU64,
}

/// A per-run span collector. Cheap to clone (an `Arc` handle); a
/// disabled trace carries no allocation at all and every operation on
/// it is a no-op.
#[derive(Clone)]
pub struct Trace {
    inner: Option<Arc<TraceInner>>,
}

impl Trace {
    /// New trace with a fresh trace id.
    pub fn new(config: &TraceConfig) -> Trace {
        Trace::build(crate::util::id::unique_id("trace"), None, 1, config)
    }

    /// Continue a wire-propagated context: same trace id, root spans
    /// parented at the caller's span id, span ids allocated above it.
    pub fn with_ctx(ctx: &TraceCtx, config: &TraceConfig) -> Trace {
        Trace::build(ctx.trace_id.clone(), Some(ctx.span_id), ctx.span_id + 1, config)
    }

    /// The no-op trace.
    pub fn disabled() -> Trace {
        Trace { inner: None }
    }

    fn build(trace_id: String, origin: Option<u64>, first_id: u64, config: &TraceConfig) -> Trace {
        if !config.enabled {
            return Trace::disabled();
        }
        Trace {
            inner: Some(Arc::new(TraceInner {
                trace_id,
                origin,
                epoch: Instant::now(),
                epoch_wall_us: crate::util::now_micros(),
                next_id: AtomicU64::new(first_id),
                spans: Mutex::new(Vec::new()),
                max_spans: config.max_spans.max(1),
                truncated: AtomicU64::new(0),
            })),
        }
    }

    /// `false` for [`Trace::disabled`].
    pub fn is_enabled(&self) -> bool {
        self.inner.is_some()
    }

    /// The trace id (`None` when disabled).
    pub fn trace_id(&self) -> Option<&str> {
        self.inner.as_deref().map(|i| i.trace_id.as_str())
    }

    fn now_us(inner: &TraceInner) -> u64 {
        inner.epoch_wall_us + inner.epoch.elapsed().as_micros() as u64
    }

    /// Start a root span (parented at the wire origin, if any).
    pub fn span(&self, name: &str) -> Span {
        let parent = self.inner.as_deref().and_then(|i| i.origin);
        self.start_span(name, parent)
    }

    fn start_span(&self, name: &str, parent: Option<u64>) -> Span {
        match self.inner.as_deref() {
            None => Span::noop(),
            Some(inner) => Span {
                trace: self.clone(),
                id: inner.next_id.fetch_add(1, Ordering::Relaxed),
                parent,
                name: name.to_string(),
                start_us: Trace::now_us(inner),
                attrs: Mutex::new(Vec::new()),
                error: Mutex::new(None),
                live: true,
            },
        }
    }

    fn push(&self, record: SpanRecord) {
        let Some(inner) = self.inner.as_deref() else { return };
        let mut spans = inner.spans.lock().unwrap();
        if spans.len() >= inner.max_spans {
            inner.truncated.fetch_add(1, Ordering::Relaxed);
            return;
        }
        spans.push(record);
    }

    /// Spans dropped past the cap so far.
    pub fn truncated(&self) -> u64 {
        self.inner
            .as_deref()
            .map(|i| i.truncated.load(Ordering::Relaxed))
            .unwrap_or(0)
    }

    /// Canonical-JSON encoding of every *finished* span (id order).
    /// This is what `JournalOp::RunTrace` journals; finish all spans
    /// before calling.
    pub fn to_json(&self) -> Json {
        let Some(inner) = self.inner.as_deref() else {
            return Json::Null;
        };
        let mut spans = inner.spans.lock().unwrap().clone();
        spans.sort_by_key(|s| s.id);
        Json::obj(vec![
            ("trace_id", Json::str(&inner.trace_id)),
            (
                "origin",
                match inner.origin {
                    Some(o) => Json::num(o as f64),
                    None => Json::Null,
                },
            ),
            ("truncated", Json::num(inner.truncated.load(Ordering::Relaxed) as f64)),
            ("spans", Json::Arr(spans.iter().map(|s| s.to_json()).collect())),
        ])
    }

    /// Human tree rendering of a trace's JSON (the `bauplan trace`
    /// default output): indentation from parent links, duration and
    /// status per span, attributes inline.
    pub fn render_text(trace: &Json) -> String {
        let mut out = String::new();
        let trace_id = trace.get("trace_id").as_str().unwrap_or("?");
        let truncated = trace.get("truncated").as_f64().unwrap_or(0.0) as u64;
        out.push_str(&format!("trace {trace_id}\n"));
        if truncated > 0 {
            out.push_str(&format!("  ({truncated} span(s) dropped past the cap)\n"));
        }
        let spans = trace.get("spans").as_arr().unwrap_or(&[]);
        // depth from parent links: parents always have smaller ids and
        // the encoding is id-ordered, so one forward pass suffices
        let mut depth: std::collections::BTreeMap<u64, usize> = std::collections::BTreeMap::new();
        for s in spans {
            let id = s.get("id").as_f64().unwrap_or(0.0) as u64;
            let d = s
                .get("parent")
                .as_f64()
                .and_then(|p| depth.get(&(p as u64)).copied())
                .map(|d| d + 1)
                .unwrap_or(0);
            depth.insert(id, d);
            let dur = s.get("end_us").as_f64().unwrap_or(0.0)
                - s.get("start_us").as_f64().unwrap_or(0.0);
            let status = s.get("status").as_str().unwrap_or("?");
            let mark = if status == "ok" { "" } else { " !" };
            let attrs = s
                .get("attrs")
                .as_obj()
                .map(|o| {
                    o.iter()
                        .map(|(k, v)| format!("{k}={v}"))
                        .collect::<Vec<_>>()
                        .join(" ")
                })
                .unwrap_or_default();
            out.push_str(&format!(
                "  {:indent$}{} {:>8.0}us{}  {}\n",
                "",
                s.get("name").as_str().unwrap_or("?"),
                dur,
                mark,
                attrs,
                indent = d * 2
            ));
        }
        out
    }
}

/// One in-flight span. Records itself into its [`Trace`] when dropped
/// (or via [`Span::finish`]); attributes are set through interior
/// mutability so a span can be shared by reference across the
/// scheduler's node threads.
pub struct Span {
    trace: Trace,
    id: u64,
    parent: Option<u64>,
    name: String,
    start_us: u64,
    attrs: Mutex<Vec<(String, Json)>>,
    error: Mutex<Option<String>>,
    live: bool,
}

impl Span {
    fn noop() -> Span {
        Span {
            trace: Trace::disabled(),
            id: 0,
            parent: None,
            name: String::new(),
            start_us: 0,
            attrs: Mutex::new(Vec::new()),
            error: Mutex::new(None),
            live: false,
        }
    }

    /// `false` for spans of a disabled trace.
    pub fn is_live(&self) -> bool {
        self.live
    }

    /// The context a callee (or the wire) should continue from.
    pub fn ctx(&self) -> Option<TraceCtx> {
        let trace_id = self.trace.trace_id()?.to_string();
        Some(TraceCtx { trace_id, span_id: self.id })
    }

    /// Start a child span.
    pub fn child(&self, name: &str) -> Span {
        if !self.live {
            return Span::noop();
        }
        self.trace.start_span(name, Some(self.id))
    }

    /// Attach an attribute (later writes of the same key win on render).
    pub fn attr(&self, key: &str, value: Json) {
        if self.live {
            self.attrs.lock().unwrap().push((key.to_string(), value));
        }
    }

    /// String attribute.
    pub fn attr_str(&self, key: &str, value: impl Into<String>) {
        self.attr(key, Json::Str(value.into()));
    }

    /// Integer attribute.
    pub fn attr_u64(&self, key: &str, value: u64) {
        self.attr(key, Json::num(value as f64));
    }

    /// Boolean attribute.
    pub fn attr_bool(&self, key: &str, value: bool) {
        self.attr(key, Json::Bool(value));
    }

    /// Mark the span failed; `detail` lands in the `error` attribute.
    pub fn fail(&self, detail: impl Into<String>) {
        if self.live {
            *self.error.lock().unwrap() = Some(detail.into());
        }
    }

    /// End the span now (equivalent to dropping it).
    pub fn finish(self) {}
}

impl Drop for Span {
    fn drop(&mut self) {
        if !self.live {
            return;
        }
        let Some(inner) = self.trace.inner.as_deref() else { return };
        let end_us = Trace::now_us(inner);
        let mut attrs = std::mem::take(&mut *self.attrs.lock().unwrap());
        let status = match self.error.lock().unwrap().take() {
            Some(detail) => {
                attrs.push(("error".to_string(), Json::str(detail)));
                "error".to_string()
            }
            None => "ok".to_string(),
        };
        self.trace.push(SpanRecord {
            id: self.id,
            parent: self.parent,
            name: std::mem::take(&mut self.name),
            start_us: self.start_us,
            end_us,
            status,
            attrs,
        });
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ctx_round_trips_and_rejects_garbage() {
        let ctx = TraceCtx::new();
        assert_eq!(TraceCtx::parse(&ctx.header_value()), Some(ctx.clone()));
        assert_eq!(TraceCtx::parse("no-slash"), None);
        assert_eq!(TraceCtx::parse("/7"), None);
        assert_eq!(TraceCtx::parse("t/notanumber"), None);
        assert_eq!(TraceCtx::parse(&format!("{}/x", "a".repeat(200))), None);
    }

    #[test]
    fn spans_record_nesting_status_and_attrs() {
        let t = Trace::new(&TraceConfig::default());
        {
            let root = t.span("run");
            root.attr_str("branch", "main");
            {
                let child = root.child("node:parent_table");
                child.attr_bool("cache_hit", false);
                child.attr_u64("rows", 9);
            }
            {
                let bad = root.child("commit:parent_table");
                bad.fail("boom");
            }
        }
        let j = t.to_json();
        let spans = j.get("spans").as_arr().unwrap();
        assert_eq!(spans.len(), 3);
        // id order: root first, children parented at it
        assert_eq!(spans[0].get("name").as_str(), Some("run"));
        assert_eq!(*spans[0].get("parent"), Json::Null);
        assert_eq!(spans[1].get("parent").as_f64(), spans[0].get("id").as_f64());
        assert_eq!(spans[1].get("attrs").get("rows").as_f64(), Some(9.0));
        assert_eq!(spans[2].get("status").as_str(), Some("error"));
        assert_eq!(spans[2].get("attrs").get("error").as_str(), Some("boom"));
        // intervals nest
        for s in &spans[1..] {
            assert!(s.get("start_us").as_f64() >= spans[0].get("start_us").as_f64());
            assert!(s.get("end_us").as_f64() <= spans[0].get("end_us").as_f64());
        }
        let text = Trace::render_text(&j);
        assert!(text.contains("node:parent_table"));
    }

    #[test]
    fn disabled_trace_is_a_noop() {
        let t = Trace::disabled();
        assert!(!t.is_enabled());
        let s = t.span("run");
        assert!(!s.is_live());
        assert!(s.ctx().is_none());
        let c = s.child("x");
        c.attr_u64("k", 1);
        drop(c);
        drop(s);
        assert_eq!(t.to_json(), Json::Null);
    }

    #[test]
    fn cap_truncates_and_counts() {
        let t = Trace::new(&TraceConfig { enabled: true, max_spans: 2 });
        for i in 0..5 {
            t.span(&format!("s{i}"));
        }
        assert_eq!(t.truncated(), 3);
        let j = t.to_json();
        assert_eq!(j.get("spans").as_arr().unwrap().len(), 2);
        assert_eq!(j.get("truncated").as_f64(), Some(3.0));
    }

    #[test]
    fn wire_ctx_continues_the_trace() {
        let ctx = TraceCtx { trace_id: "trace_abc".into(), span_id: 7 };
        let t = Trace::with_ctx(&ctx, &TraceConfig::default());
        let root = t.span("server.request");
        assert_eq!(root.ctx().unwrap().trace_id, "trace_abc");
        assert!(root.ctx().unwrap().span_id > 7, "ids allocate above the origin");
        drop(root);
        let j = t.to_json();
        assert_eq!(j.get("origin").as_f64(), Some(7.0));
        assert_eq!(j.get("spans").as_arr().unwrap()[0].get("parent").as_f64(), Some(7.0));
    }
}
