//! The flight recorder: a fixed-size ring buffer of recent spans.
//!
//! Run spans are journaled with their run; everything else — branch
//! CRUD, merges, checkpoints, journal maintenance, HTTP requests — is
//! recorded here instead. The ring is lock-cheap (one mutex acquired
//! once per *finished* span, never on the hot path inside a span) and
//! fixed-size: old spans are overwritten, a monotonic `dropped` counter
//! says how many. The point is the post-mortem: when the catalog
//! poisons itself, recovery fails, or the server shuts down, the last N
//! operations are dumped to `<lake>/flight/` as canonical JSON —
//! exactly the "what was in flight?" evidence the paper's failure
//! triage needs. Live view: `GET /v1/trace/flight`.

use std::collections::VecDeque;
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Instant;

use crate::trace::SpanRecord;
use crate::util::json::Json;

/// Subdirectory of a lake dir that flight dumps land in.
pub const FLIGHT_DIR: &str = "flight";

/// Default ring capacity (spans).
pub const DEFAULT_FLIGHT_CAP: usize = 256;

struct FlightInner {
    cap: usize,
    ring: Mutex<VecDeque<SpanRecord>>,
    dropped: AtomicU64,
    next_id: AtomicU64,
    epoch: Instant,
    epoch_wall_us: u64,
}

/// Cloneable handle to one ring buffer (an `Arc` inside).
#[derive(Clone)]
pub struct FlightRecorder {
    inner: Arc<FlightInner>,
}

impl FlightRecorder {
    /// Ring of at most `cap` spans.
    pub fn new(cap: usize) -> FlightRecorder {
        FlightRecorder {
            inner: Arc::new(FlightInner {
                cap: cap.max(1),
                ring: Mutex::new(VecDeque::new()),
                dropped: AtomicU64::new(0),
                next_id: AtomicU64::new(1),
                epoch: Instant::now(),
                epoch_wall_us: crate::util::now_micros(),
            }),
        }
    }

    fn now_us(&self) -> u64 {
        self.inner.epoch_wall_us + self.inner.epoch.elapsed().as_micros() as u64
    }

    /// Start a span; it enters the ring when dropped (or finished).
    pub fn begin(&self, name: &str) -> FlightSpan {
        FlightSpan {
            rec: self.clone(),
            id: self.inner.next_id.fetch_add(1, Ordering::Relaxed),
            name: name.to_string(),
            start_us: self.now_us(),
            status: "ok".to_string(),
            attrs: Vec::new(),
        }
    }

    fn push(&self, record: SpanRecord) {
        let mut ring = self.inner.ring.lock().unwrap();
        if ring.len() >= self.inner.cap {
            ring.pop_front();
            self.inner.dropped.fetch_add(1, Ordering::Relaxed);
        }
        ring.push_back(record);
    }

    /// Spans currently in the ring.
    pub fn len(&self) -> usize {
        self.inner.ring.lock().unwrap().len()
    }

    /// Spans overwritten since creation (the truncation counter).
    pub fn dropped(&self) -> u64 {
        self.inner.dropped.load(Ordering::Relaxed)
    }

    /// Canonical-JSON snapshot: capacity, overwrite count, and the
    /// retained spans oldest-first.
    pub fn to_json(&self) -> Json {
        let ring = self.inner.ring.lock().unwrap();
        Json::obj(vec![
            ("cap", Json::num(self.inner.cap as f64)),
            ("dropped", Json::num(self.inner.dropped.load(Ordering::Relaxed) as f64)),
            ("spans", Json::Arr(ring.iter().map(|s| s.to_json()).collect())),
        ])
    }

    /// Dump the ring to `<dir>/flight/flight-<µs>-<reason>.json` and
    /// return the path. Best-effort callers ignore the error — a dump
    /// must never turn a poisoning into a second failure.
    pub fn dump(&self, dir: &Path, reason: &str) -> std::io::Result<PathBuf> {
        let flight_dir = dir.join(FLIGHT_DIR);
        std::fs::create_dir_all(&flight_dir)?;
        // keep the filename shell-safe whatever the reason string holds
        let slug: String = reason
            .chars()
            .map(|c| if c.is_ascii_alphanumeric() { c } else { '_' })
            .take(48)
            .collect();
        let path = flight_dir.join(format!("flight-{:016}-{slug}.json", self.now_us()));
        let doc = Json::obj(vec![
            ("reason", Json::str(reason)),
            ("dumped_at_us", Json::num(self.now_us() as f64)),
            ("flight", self.to_json()),
        ]);
        std::fs::write(&path, format!("{doc}\n"))?;
        Ok(path)
    }
}

/// One in-flight recorder span. Attribute setters take `&mut self` —
/// a flight span has a single owner, so no interior locking.
pub struct FlightSpan {
    rec: FlightRecorder,
    id: u64,
    name: String,
    start_us: u64,
    status: String,
    attrs: Vec<(String, Json)>,
}

impl FlightSpan {
    /// Attach an attribute.
    pub fn attr(&mut self, key: &str, value: Json) {
        self.attrs.push((key.to_string(), value));
    }

    /// String attribute.
    pub fn attr_str(&mut self, key: &str, value: impl Into<String>) {
        self.attr(key, Json::Str(value.into()));
    }

    /// Integer attribute.
    pub fn attr_u64(&mut self, key: &str, value: u64) {
        self.attr(key, Json::num(value as f64));
    }

    /// Mark the span failed.
    pub fn fail(&mut self, detail: impl Into<String>) {
        self.status = "error".to_string();
        self.attrs.push(("error".to_string(), Json::str(detail.into())));
    }

    /// End the span now (equivalent to dropping it).
    pub fn finish(self) {}
}

impl Drop for FlightSpan {
    fn drop(&mut self) {
        let end_us = self.rec.now_us();
        self.rec.push(SpanRecord {
            id: self.id,
            parent: None,
            name: std::mem::take(&mut self.name),
            start_us: self.start_us,
            end_us,
            status: std::mem::take(&mut self.status),
            attrs: std::mem::take(&mut self.attrs),
        });
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ring_wraps_and_counts_overwrites() {
        let fr = FlightRecorder::new(3);
        for i in 0..7 {
            let mut s = fr.begin(&format!("op{i}"));
            s.attr_u64("i", i);
        }
        assert_eq!(fr.len(), 3);
        assert_eq!(fr.dropped(), 4);
        let j = fr.to_json();
        let spans = j.get("spans").as_arr().unwrap();
        // oldest-first, only the newest cap survive
        assert_eq!(spans[0].get("name").as_str(), Some("op4"));
        assert_eq!(spans[2].get("name").as_str(), Some("op6"));
        assert_eq!(j.get("dropped").as_f64(), Some(4.0));
        assert_eq!(j.get("cap").as_f64(), Some(3.0));
    }

    #[test]
    fn failed_spans_keep_status_and_detail() {
        let fr = FlightRecorder::new(8);
        let mut s = fr.begin("journal.group_sync");
        s.attr_u64("batch", 5);
        s.fail("fsync: disk gone");
        drop(s);
        let j = fr.to_json();
        let span = &j.get("spans").as_arr().unwrap()[0];
        assert_eq!(span.get("status").as_str(), Some("error"));
        assert_eq!(span.get("attrs").get("error").as_str(), Some("fsync: disk gone"));
        assert_eq!(span.get("attrs").get("batch").as_f64(), Some(5.0));
    }

    #[test]
    fn dump_writes_canonical_json_under_flight_dir() {
        let dir = std::env::temp_dir()
            .join(format!("bpl_flight_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        let fr = FlightRecorder::new(4);
        fr.begin("catalog.commit").finish();
        let path = fr.dump(&dir, "poisoned: fsync failed").unwrap();
        assert!(path.starts_with(dir.join(FLIGHT_DIR)));
        let text = std::fs::read_to_string(&path).unwrap();
        let doc = Json::parse(text.trim()).unwrap();
        assert_eq!(doc.get("reason").as_str(), Some("poisoned: fsync failed"));
        assert_eq!(doc.get("flight").get("spans").as_arr().unwrap().len(), 1);
        let _ = std::fs::remove_dir_all(&dir);
    }
}
