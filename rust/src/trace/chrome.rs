//! Chrome `trace_event` export: load a run trace in `chrome://tracing`
//! / Perfetto and read it as a flamegraph.
//!
//! Input is the canonical trace JSON ([`crate::trace::Trace::to_json`]
//! or a flight-recorder snapshot); output is the trace-event "JSON
//! object format": `{"traceEvents": [...]}` of complete (`"ph": "X"`)
//! events with microsecond timestamps. Lane assignment (`tid`): spans
//! under a `node:<name>` span share that node's lane, so a wavefront of
//! concurrent nodes renders as parallel tracks; everything else rides
//! lane 1.

use std::collections::BTreeMap;

use crate::util::json::Json;

/// Convert canonical trace JSON into Chrome trace-event JSON.
///
/// Unknown / malformed spans are skipped rather than erroring — the
/// exporter is a viewer aid, not a validator.
pub fn chrome_trace_events(trace: &Json) -> Json {
    let spans = trace.get("spans").as_arr().unwrap_or(&[]);
    // lane per span id: node spans open their own lane, children
    // inherit it (parents precede children in the id-ordered encoding)
    let mut lane: BTreeMap<u64, u64> = BTreeMap::new();
    let mut events: Vec<Json> = Vec::with_capacity(spans.len());
    for s in spans {
        let Some(id) = s.get("id").as_f64().map(|v| v as u64) else { continue };
        let name = s.get("name").as_str().unwrap_or("span");
        let parent = s.get("parent").as_f64().map(|v| v as u64);
        let tid = if name.starts_with("node:") {
            id
        } else {
            parent.and_then(|p| lane.get(&p).copied()).unwrap_or(1)
        };
        lane.insert(id, tid);
        let start = s.get("start_us").as_f64().unwrap_or(0.0);
        let end = s.get("end_us").as_f64().unwrap_or(start);
        let mut args: BTreeMap<String, Json> = s
            .get("attrs")
            .as_obj()
            .cloned()
            .unwrap_or_default();
        args.insert("span_id".to_string(), Json::num(id as f64));
        if let Some(p) = parent {
            args.insert("parent_span_id".to_string(), Json::num(p as f64));
        }
        args.insert(
            "status".to_string(),
            Json::str(s.get("status").as_str().unwrap_or("ok")),
        );
        events.push(Json::obj(vec![
            ("name", Json::str(name)),
            ("cat", Json::str("bauplan")),
            ("ph", Json::str("X")),
            ("ts", Json::num(start)),
            ("dur", Json::num((end - start).max(0.0))),
            ("pid", Json::num(1.0)),
            ("tid", Json::num(tid as f64)),
            ("args", Json::Obj(args)),
        ]));
    }
    Json::obj(vec![
        ("traceEvents", Json::Arr(events)),
        ("displayTimeUnit", Json::str("ms")),
        (
            "otherData",
            Json::obj(vec![(
                "trace_id",
                Json::str(trace.get("trace_id").as_str().unwrap_or("")),
            )]),
        ),
    ])
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::trace::{Trace, TraceConfig};

    #[test]
    fn exports_complete_events_with_node_lanes() {
        let t = Trace::new(&TraceConfig::default());
        {
            let run = t.span("run");
            let sched = run.child("scheduler");
            let n0 = sched.child("node:parent_table");
            let c0 = n0.child("commit:parent_table");
            drop(c0);
            drop(n0);
            let n1 = sched.child("node:child_table");
            drop(n1);
        }
        let chrome = chrome_trace_events(&t.to_json());
        let events = chrome.get("traceEvents").as_arr().unwrap();
        assert_eq!(events.len(), 5);
        for e in events {
            assert_eq!(e.get("ph").as_str(), Some("X"));
            assert_eq!(e.get("pid").as_f64(), Some(1.0));
            assert!(e.get("ts").as_f64().is_some());
            assert!(e.get("dur").as_f64().unwrap() >= 0.0);
            assert!(e.get("tid").as_f64().is_some());
            assert!(e.get("args").get("span_id").as_f64().is_some());
        }
        // run + scheduler ride lane 1; each node opens its own lane and
        // its commit child inherits it
        let by_name = |n: &str| {
            events
                .iter()
                .find(|e| e.get("name").as_str() == Some(n))
                .unwrap()
        };
        assert_eq!(by_name("run").get("tid").as_f64(), Some(1.0));
        assert_eq!(by_name("scheduler").get("tid").as_f64(), Some(1.0));
        let n0_tid = by_name("node:parent_table").get("tid").as_f64().unwrap();
        assert_ne!(n0_tid, 1.0);
        assert_eq!(by_name("commit:parent_table").get("tid").as_f64(), Some(n0_tid));
        assert_ne!(by_name("node:child_table").get("tid").as_f64(), Some(n0_tid));
        // the whole document parses back (valid JSON shape)
        assert!(Json::parse(&chrome.to_string()).is_ok());
    }

    #[test]
    fn tolerates_malformed_spans() {
        let doc = Json::parse(r#"{"spans":[{"name":"x"},{"id":3,"name":"y"}]}"#).unwrap();
        let chrome = chrome_trace_events(&doc);
        assert_eq!(chrome.get("traceEvents").as_arr().unwrap().len(), 1);
    }
}
