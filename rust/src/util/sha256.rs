//! Vendored SHA-256 (FIPS 180-4) — the offline crate set has no `sha2`.
//!
//! A straightforward, dependency-free implementation of the standard
//! algorithm with the streaming `new` / `update` / `finalize` shape the
//! rest of the crate uses for content addressing. Correctness is pinned
//! by the FIPS test vectors plus padding-boundary cases (55/56/64/65
//! byte messages) in the unit tests below; `hashlib.sha256` produced the
//! expected digests.
//!
//! Performance note: commits and snapshots hash a few hundred bytes, and
//! data objects hash once per PUT — this scalar implementation (~2 GB/s
//! in release builds) is nowhere near any hot path the benches measure.

/// Round constants: first 32 bits of the fractional parts of the cube
/// roots of the first 64 primes.
const K: [u32; 64] = [
    0x428a2f98, 0x71374491, 0xb5c0fbcf, 0xe9b5dba5, 0x3956c25b, 0x59f111f1,
    0x923f82a4, 0xab1c5ed5, 0xd807aa98, 0x12835b01, 0x243185be, 0x550c7dc3,
    0x72be5d74, 0x80deb1fe, 0x9bdc06a7, 0xc19bf174, 0xe49b69c1, 0xefbe4786,
    0x0fc19dc6, 0x240ca1cc, 0x2de92c6f, 0x4a7484aa, 0x5cb0a9dc, 0x76f988da,
    0x983e5152, 0xa831c66d, 0xb00327c8, 0xbf597fc7, 0xc6e00bf3, 0xd5a79147,
    0x06ca6351, 0x14292967, 0x27b70a85, 0x2e1b2138, 0x4d2c6dfc, 0x53380d13,
    0x650a7354, 0x766a0abb, 0x81c2c92e, 0x92722c85, 0xa2bfe8a1, 0xa81a664b,
    0xc24b8b70, 0xc76c51a3, 0xd192e819, 0xd6990624, 0xf40e3585, 0x106aa070,
    0x19a4c116, 0x1e376c08, 0x2748774c, 0x34b0bcb5, 0x391c0cb3, 0x4ed8aa4a,
    0x5b9cca4f, 0x682e6ff3, 0x748f82ee, 0x78a5636f, 0x84c87814, 0x8cc70208,
    0x90befffa, 0xa4506ceb, 0xbef9a3f7, 0xc67178f2,
];

/// Initial hash state: first 32 bits of the fractional parts of the
/// square roots of the first 8 primes.
const H0: [u32; 8] = [
    0x6a09e667, 0xbb67ae85, 0x3c6ef372, 0xa54ff53a,
    0x510e527f, 0x9b05688c, 0x1f83d9ab, 0x5be0cd19,
];

/// Streaming SHA-256 hasher.
///
/// ```
/// use bauplan::util::sha256::Sha256;
/// let mut h = Sha256::new();
/// h.update(b"abc");
/// let digest = h.finalize();
/// assert_eq!(digest[0], 0xba);
/// ```
#[derive(Clone)]
pub struct Sha256 {
    state: [u32; 8],
    /// Partial input block awaiting 64 accumulated bytes.
    buf: [u8; 64],
    buf_len: usize,
    /// Total message length in bytes (the padding trailer needs bits).
    total_len: u64,
}

impl Default for Sha256 {
    fn default() -> Self {
        Self::new()
    }
}

impl Sha256 {
    /// Fresh hasher in the initial state.
    pub fn new() -> Sha256 {
        Sha256 { state: H0, buf: [0u8; 64], buf_len: 0, total_len: 0 }
    }

    /// Absorb `data`; may be called any number of times.
    pub fn update(&mut self, data: impl AsRef<[u8]>) {
        let mut data = data.as_ref();
        self.total_len = self.total_len.wrapping_add(data.len() as u64);
        // top up a partial block first
        if self.buf_len > 0 {
            let take = (64 - self.buf_len).min(data.len());
            self.buf[self.buf_len..self.buf_len + take].copy_from_slice(&data[..take]);
            self.buf_len += take;
            data = &data[take..];
            if self.buf_len == 64 {
                let block = self.buf;
                self.compress(&block);
                self.buf_len = 0;
            }
        }
        // whole blocks straight from the input
        while data.len() >= 64 {
            let mut block = [0u8; 64];
            block.copy_from_slice(&data[..64]);
            self.compress(&block);
            data = &data[64..];
        }
        // stash the tail
        if !data.is_empty() {
            self.buf[..data.len()].copy_from_slice(data);
            self.buf_len = data.len();
        }
    }

    /// Apply the FIPS padding and return the 32-byte digest.
    pub fn finalize(mut self) -> [u8; 32] {
        let bit_len = self.total_len.wrapping_mul(8);
        // 0x80 terminator, then zeros until 8 bytes remain in the block
        self.update([0x80u8]);
        self.total_len = self.total_len.wrapping_sub(1); // padding is not message
        while self.buf_len != 56 {
            self.update([0u8]);
            self.total_len = self.total_len.wrapping_sub(1);
        }
        self.update(bit_len.to_be_bytes());
        debug_assert_eq!(self.buf_len, 0);
        let mut out = [0u8; 32];
        for (i, w) in self.state.iter().enumerate() {
            out[i * 4..i * 4 + 4].copy_from_slice(&w.to_be_bytes());
        }
        out
    }

    /// One-shot convenience.
    pub fn digest(data: &[u8]) -> [u8; 32] {
        let mut h = Sha256::new();
        h.update(data);
        h.finalize()
    }

    fn compress(&mut self, block: &[u8; 64]) {
        let mut w = [0u32; 64];
        for i in 0..16 {
            w[i] = u32::from_be_bytes([
                block[i * 4], block[i * 4 + 1], block[i * 4 + 2], block[i * 4 + 3],
            ]);
        }
        for i in 16..64 {
            let s0 = w[i - 15].rotate_right(7) ^ w[i - 15].rotate_right(18) ^ (w[i - 15] >> 3);
            let s1 = w[i - 2].rotate_right(17) ^ w[i - 2].rotate_right(19) ^ (w[i - 2] >> 10);
            w[i] = w[i - 16]
                .wrapping_add(s0)
                .wrapping_add(w[i - 7])
                .wrapping_add(s1);
        }
        let [mut a, mut b, mut c, mut d, mut e, mut f, mut g, mut h] = self.state;
        for i in 0..64 {
            let s1 = e.rotate_right(6) ^ e.rotate_right(11) ^ e.rotate_right(25);
            let ch = (e & f) ^ (!e & g);
            let t1 = h
                .wrapping_add(s1)
                .wrapping_add(ch)
                .wrapping_add(K[i])
                .wrapping_add(w[i]);
            let s0 = a.rotate_right(2) ^ a.rotate_right(13) ^ a.rotate_right(22);
            let maj = (a & b) ^ (a & c) ^ (b & c);
            let t2 = s0.wrapping_add(maj);
            h = g;
            g = f;
            f = e;
            e = d.wrapping_add(t1);
            d = c;
            c = b;
            b = a;
            a = t1.wrapping_add(t2);
        }
        self.state[0] = self.state[0].wrapping_add(a);
        self.state[1] = self.state[1].wrapping_add(b);
        self.state[2] = self.state[2].wrapping_add(c);
        self.state[3] = self.state[3].wrapping_add(d);
        self.state[4] = self.state[4].wrapping_add(e);
        self.state[5] = self.state[5].wrapping_add(f);
        self.state[6] = self.state[6].wrapping_add(g);
        self.state[7] = self.state[7].wrapping_add(h);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn hexdigest(data: &[u8]) -> String {
        Sha256::digest(data)
            .iter()
            .map(|b| format!("{b:02x}"))
            .collect()
    }

    #[test]
    fn fips_vectors() {
        assert_eq!(
            hexdigest(b"abc"),
            "ba7816bf8f01cfea414140de5dae2223b00361a396177a9cb410ff61f20015ad"
        );
        assert_eq!(
            hexdigest(b""),
            "e3b0c44298fc1c149afbf4c8996fb92427ae41e4649b934ca495991b7852b855"
        );
        assert_eq!(
            hexdigest(b"The quick brown fox jumps over the lazy dog"),
            "d7a8fbb307d7809469ca9abcb0082e4f8d5651e46d3cdb762d02d0bf37c9e592"
        );
    }

    #[test]
    fn padding_boundaries() {
        // 55 bytes: padding fits in one block; 56: spills into a second;
        // 64: exactly one block of message; 65: one block + 1 byte.
        assert_eq!(
            hexdigest(&[b'x'; 55]),
            "d5e285683cd4efc02d021a5c62014694958901005d6f71e89e0989fac77e4072"
        );
        assert_eq!(
            hexdigest(&[b'x'; 56]),
            "04c26261370ee7541549d16dee320c723e3fd14671e66a099afe0a377c16888e"
        );
        assert_eq!(
            hexdigest(&[b'x'; 64]),
            "7ce100971f64e7001e8fe5a51973ecdfe1ced42befe7ee8d5fd6219506b5393c"
        );
        assert_eq!(
            hexdigest(&[b'x'; 65]),
            "9537c5fdf120482f7d58d25e9ed583f52c02b4e304ea814db1633ad565aed7e9"
        );
    }

    #[test]
    fn long_input_and_split_updates() {
        let expected = "41edece42d63e8d9bf515a9ba6932e1c20cbc9f5a5d134645adb5db1b9737ea3";
        assert_eq!(hexdigest(&[b'a'; 1000]), expected);
        // identical digest regardless of update chunking
        let mut h = Sha256::new();
        for chunk in [b'a'; 1000].chunks(77) {
            h.update(chunk);
        }
        let split: String = h.finalize().iter().map(|b| format!("{b:02x}")).collect();
        assert_eq!(split, expected);
    }
}
