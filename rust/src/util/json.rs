//! Minimal JSON: a `Value` tree, a recursive-descent parser and a writer.
//!
//! Used for the AOT `manifest.json`, catalog export/import, and the CLI's
//! machine-readable output. Covers the full JSON grammar (objects, arrays,
//! strings with escapes, numbers, bools, null); numbers are f64, which is
//! exact for every integer the manifest contains (< 2^53).

use std::collections::BTreeMap;
use std::fmt;

use crate::error::{BauplanError, Result};

/// A parsed JSON value. Object keys are ordered (BTreeMap) so serialization
/// is deterministic — catalog exports are content-hashed.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn parse(s: &str) -> Result<Json> {
        let mut p = Parser { b: s.as_bytes(), i: 0 };
        p.ws();
        let v = p.value()?;
        p.ws();
        if p.i != p.b.len() {
            return Err(BauplanError::Parse(format!("trailing bytes at offset {}", p.i)));
        }
        Ok(v)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }
    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|n| n as usize)
    }
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }
    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }
    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(o) => Some(o),
            _ => None,
        }
    }
    /// `obj["k"]`-style access; returns Null for missing keys / non-objects.
    pub fn get(&self, key: &str) -> &Json {
        static NULL: Json = Json::Null;
        self.as_obj().and_then(|o| o.get(key)).unwrap_or(&NULL)
    }

    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }
    pub fn str(s: impl Into<String>) -> Json {
        Json::Str(s.into())
    }
    pub fn num(n: impl Into<f64>) -> Json {
        Json::Num(n.into())
    }
}

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Json::Null => write!(f, "null"),
            Json::Bool(b) => write!(f, "{b}"),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 9e15 {
                    write!(f, "{}", *n as i64)
                } else {
                    write!(f, "{n}")
                }
            }
            Json::Str(s) => write_escaped(f, s),
            Json::Arr(a) => {
                write!(f, "[")?;
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write!(f, "{v}")?;
                }
                write!(f, "]")
            }
            Json::Obj(o) => {
                write!(f, "{{")?;
                for (i, (k, v)) in o.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write_escaped(f, k)?;
                    write!(f, ":{v}")?;
                }
                write!(f, "}}")
            }
        }
    }
}

fn write_escaped(f: &mut fmt::Formatter<'_>, s: &str) -> fmt::Result {
    write!(f, "\"")?;
    for c in s.chars() {
        match c {
            '"' => write!(f, "\\\"")?,
            '\\' => write!(f, "\\\\")?,
            '\n' => write!(f, "\\n")?,
            '\r' => write!(f, "\\r")?,
            '\t' => write!(f, "\\t")?,
            c if (c as u32) < 0x20 => write!(f, "\\u{:04x}", c as u32)?,
            c => write!(f, "{c}")?,
        }
    }
    write!(f, "\"")
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn expect(&mut self, c: u8) -> Result<()> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(BauplanError::Parse(format!("expected '{}' at offset {}", c as char, self.i)))
        }
    }

    fn value(&mut self) -> Result<Json> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(BauplanError::Parse(format!("unexpected byte at offset {}", self.i))),
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            Err(BauplanError::Parse(format!("bad literal at offset {}", self.i)))
        }
    }

    fn number(&mut self) -> Result<Json> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while let Some(c) = self.peek() {
            if c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-') {
                self.i += 1;
            } else {
                break;
            }
        }
        let text = std::str::from_utf8(&self.b[start..self.i])
            .map_err(|_| BauplanError::Parse("bad utf8 in number".into()))?;
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| BauplanError::Parse(format!("bad number '{text}'")))
    }

    fn string(&mut self) -> Result<String> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return Err(BauplanError::Parse("unterminated string".into())),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.i += 1;
                    match self.peek() {
                        Some(b'"') => {
                            out.push('"');
                            self.i += 1;
                        }
                        Some(b'\\') => {
                            out.push('\\');
                            self.i += 1;
                        }
                        Some(b'/') => {
                            out.push('/');
                            self.i += 1;
                        }
                        Some(b'n') => {
                            out.push('\n');
                            self.i += 1;
                        }
                        Some(b'r') => {
                            out.push('\r');
                            self.i += 1;
                        }
                        Some(b't') => {
                            out.push('\t');
                            self.i += 1;
                        }
                        Some(b'b') => {
                            out.push('\u{8}');
                            self.i += 1;
                        }
                        Some(b'f') => {
                            out.push('\u{c}');
                            self.i += 1;
                        }
                        Some(b'u') => {
                            self.i += 1;
                            if self.i + 4 > self.b.len() {
                                return Err(BauplanError::Parse("bad \\u".into()));
                            }
                            let hex = std::str::from_utf8(&self.b[self.i..self.i + 4])
                                .map_err(|_| BauplanError::Parse("bad \\u".into()))?;
                            let cp = u32::from_str_radix(hex, 16)
                                .map_err(|_| BauplanError::Parse("bad \\u".into()))?;
                            out.push(char::from_u32(cp).unwrap_or('\u{fffd}'));
                            self.i += 4;
                        }
                        _ => return Err(BauplanError::Parse("bad escape".into())),
                    }
                }
                Some(_) => {
                    // copy a full utf8 scalar
                    let s = std::str::from_utf8(&self.b[self.i..])
                        .map_err(|_| BauplanError::Parse("bad utf8".into()))?;
                    let c = s.chars().next().unwrap();
                    out.push(c);
                    self.i += c.len_utf8();
                }
            }
        }
    }

    fn object(&mut self) -> Result<Json> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.ws();
            let k = self.string()?;
            self.ws();
            self.expect(b':')?;
            self.ws();
            let v = self.value()?;
            map.insert(k, v);
            self.ws();
            match self.peek() {
                Some(b',') => {
                    self.i += 1;
                }
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(map));
                }
                _ => {
                    return Err(BauplanError::Parse(format!(
                        "expected ',' or '}}' at offset {}",
                        self.i
                    )))
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json> {
        self.expect(b'[')?;
        let mut arr = Vec::new();
        self.ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(arr));
        }
        loop {
            self.ws();
            arr.push(self.value()?);
            self.ws();
            match self.peek() {
                Some(b',') => {
                    self.i += 1;
                }
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(arr));
                }
                _ => {
                    return Err(BauplanError::Parse(format!(
                        "expected ',' or ']' at offset {}",
                        self.i
                    )))
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("-3.5e2").unwrap(), Json::Num(-350.0));
        assert_eq!(Json::parse("\"a\\nb\"").unwrap(), Json::Str("a\nb".into()));
    }

    #[test]
    fn parses_nested() {
        let v = Json::parse(r#"{"a": [1, 2, {"b": "x"}], "c": null}"#).unwrap();
        assert_eq!(v.get("a").as_arr().unwrap().len(), 3);
        assert_eq!(v.get("a").as_arr().unwrap()[2].get("b").as_str(), Some("x"));
        assert_eq!(*v.get("c"), Json::Null);
        assert_eq!(*v.get("missing"), Json::Null);
    }

    #[test]
    fn round_trips() {
        let src = r#"{"arr":[1,2.5,"s"],"b":false,"n":null,"s":"q\"uote"}"#;
        let v = Json::parse(src).unwrap();
        let out = v.to_string();
        assert_eq!(Json::parse(&out).unwrap(), v);
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("tru").is_err());
        assert!(Json::parse("1 2").is_err());
        assert!(Json::parse("\"unterminated").is_err());
    }

    #[test]
    fn unicode_escapes() {
        assert_eq!(Json::parse("\"\\u0041\"").unwrap(), Json::Str("A".into()));
        let v = Json::Str("tab\there".into());
        assert_eq!(Json::parse(&v.to_string()).unwrap(), v);
    }

    #[test]
    fn integers_serialize_without_fraction() {
        assert_eq!(Json::Num(42.0).to_string(), "42");
        assert_eq!(Json::Num(0.5).to_string(), "0.5");
    }
}
