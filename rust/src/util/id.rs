//! Content hashes and unique ids.
//!
//! Commits and snapshots are content-addressed (sha256 over a canonical
//! encoding) — the same trick Git uses, and what makes branch/merge
//! zero-copy: two branches pointing at equal content share the object.

use crate::util::sha256::Sha256;
use std::sync::atomic::{AtomicU64, Ordering};

/// Hex sha256 digest of `data`, truncated to 16 bytes (32 hex chars) —
/// plenty for a laptop-scale lake, and keeps log lines readable.
pub fn content_hash(data: &[u8]) -> String {
    let mut h = Sha256::new();
    h.update(data);
    let out = h.finalize();
    hex(&out[..16])
}

/// Hash of several parts with unambiguous framing (length-prefixed).
pub fn content_hash_parts(parts: &[&[u8]]) -> String {
    let mut h = Sha256::new();
    for p in parts {
        h.update((p.len() as u64).to_le_bytes());
        h.update(p);
    }
    let out = h.finalize();
    hex(&out[..16])
}

fn hex(bytes: &[u8]) -> String {
    let mut s = String::with_capacity(bytes.len() * 2);
    for b in bytes {
        s.push_str(&format!("{b:02x}"));
    }
    s
}

static COUNTER: AtomicU64 = AtomicU64::new(1);

/// Process-unique id with a readable prefix, e.g. `run_000000002a`.
/// A timestamp component makes ids unique across process restarts too.
pub fn unique_id(prefix: &str) -> String {
    let n = COUNTER.fetch_add(1, Ordering::Relaxed);
    let t = crate::util::now_micros() & 0xffff_ffff;
    format!("{prefix}_{t:08x}{n:06x}")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn hash_is_deterministic_and_distinct() {
        assert_eq!(content_hash(b"abc"), content_hash(b"abc"));
        assert_ne!(content_hash(b"abc"), content_hash(b"abd"));
        assert_eq!(content_hash(b"abc").len(), 32);
    }

    #[test]
    fn framing_prevents_concat_collisions() {
        // ("ab","c") must not hash like ("a","bc")
        assert_ne!(
            content_hash_parts(&[b"ab", b"c"]),
            content_hash_parts(&[b"a", b"bc"])
        );
    }

    #[test]
    fn unique_ids_are_unique() {
        let a = unique_id("run");
        let b = unique_id("run");
        assert_ne!(a, b);
        assert!(a.starts_with("run_"));
    }
}
