//! Small shared utilities: JSON (serde is unavailable in the offline crate
//! set, so we carry our own minimal codec), a vendored SHA-256 (ditto for
//! the `sha2` crate), content hashes, ids, clocks.

pub mod json;
pub mod sha256;
pub mod id;

/// Monotonic-ish wall clock in microseconds since the UNIX epoch.
pub fn now_micros() -> u64 {
    std::time::SystemTime::now()
        .duration_since(std::time::UNIX_EPOCH)
        .map(|d| d.as_micros() as u64)
        .unwrap_or(0)
}
