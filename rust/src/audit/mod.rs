//! # Lake doctor — offline `fsck` and the online integrity auditor
//!
//! The paper's thesis is that illegal states should be unrepresentable;
//! this module makes the on-disk lake's integrity *observable* rather than
//! merely enforced-at-write-time. [`fsck`] walks a lake directory strictly
//! read-only and verifies every cross-structure invariant the docs
//! promise:
//!
//! - **Journal** (`journal/seg-*.jsonl`): per-line CRCs, header/seal
//!   framing, in-segment sequence contiguity, and that the replayable
//!   tail chains onto the checkpoint cover without gaps. A torn tail in
//!   the *active* segment is a legal crash artifact (info); any damage in
//!   a *frozen* segment is an error.
//! - **Snapshot chain** (`snapshots/base-*.json` + `delta-*-*.json`):
//!   the newest base parses, in-chain deltas parse and chain contiguously,
//!   stale files are tolerated (warn on corruption — compaction retires
//!   them lazily).
//! - **Catalog state**, rebuilt by a tolerant replayer that mirrors
//!   recovery (base → deltas → journal tail, including re-running the
//!   recorded GC mark-and-sweep): every branch head and tag resolves to a
//!   commit, the parent closure is complete, every commit's tables map to
//!   live snapshots, every live snapshot's objects exist in the store.
//! - **Object store** (`objects/`): orphans are reported (info — GC owns
//!   them); `--deep` re-hashes every object against its content address
//!   and cross-checks BPB2 zone-map footers against stats recomputed from
//!   the decoded body.
//! - **Run cache** (`cache.jsonl`): index lines parse, sequence is
//!   contiguous, and surviving entries memoize live snapshots.
//! - **Runs/traces**: journaled traces have matching run records.
//!
//! Findings carry a stable machine-readable code (`AUDIT_*`), a severity,
//! the lake-relative file they indict, and a byte offset where one exists.
//! The report serializes to canonical JSON (`FsckReport::to_json`) and a
//! human summary (`FsckReport::render`). The full check taxonomy and the
//! invariant ↔ test map live in `doc/FSCK.md`.
//!
//! [`online`] wraps the same walker in a budgeted background auditor for
//! the server: time-sliced cycles, a bytes/sec throttle so audits never
//! compete with the data plane, `audit.*` metrics, and flight-recorder
//! dumps on error-severity findings.
#![warn(missing_docs)]

pub mod online;

use std::collections::{BTreeMap, BTreeSet, HashSet};
use std::path::Path;
use std::time::{Duration, Instant};

use crate::catalog::journal::{parse_seg_line, parse_segment_name, SegLine, JOURNAL_FILE};
use crate::catalog::persist::{
    branch_from_json, commit_from_json, parse_base_name, parse_delta_name, read_checkpoint_seq,
    snapshot_from_json, SNAPSHOT_DIR,
};
use crate::catalog::{BranchInfo, Commit, JournalOp, Snapshot, JOURNAL_DIR};
use crate::cache::{IndexOp, IndexRecord, CACHE_INDEX_FILE};
use crate::error::{BauplanError, Result};
use crate::storage::codec::{compute_stats, decode_batch, decode_stats};
use crate::storage::valid_object_key;
use crate::util::id::content_hash;
use crate::util::json::Json;

// ------------------------------------------------------- finding codes

/// A line in a frozen journal segment fails its CRC, does not parse, or
/// breaks the header/seal framing.
pub const AUDIT_SEGMENT_CRC: &str = "AUDIT_SEGMENT_CRC";
/// A frozen journal segment is missing its seal, or the seal disagrees
/// with the records it closes.
pub const AUDIT_SEGMENT_SEAL: &str = "AUDIT_SEGMENT_SEAL";
/// Replayable journal sequence numbers have an interior gap.
pub const AUDIT_SEGMENT_GAP: &str = "AUDIT_SEGMENT_GAP";
/// The active journal segment ends in a torn tail — a legal crash
/// artifact that recovery truncates (info severity).
pub const AUDIT_SEGMENT_TORN: &str = "AUDIT_SEGMENT_TORN";
/// A base or delta snapshot inside the live chain does not parse.
pub const AUDIT_CHECKPOINT_PARSE: &str = "AUDIT_CHECKPOINT_PARSE";
/// The journal does not chain onto the snapshot-chain cover: the record
/// right after the cover is missing.
pub const AUDIT_CHECKPOINT_CHAIN: &str = "AUDIT_CHECKPOINT_CHAIN";
/// A stale (superseded, awaiting retirement) snapshot file is corrupt or
/// does not chain.
pub const AUDIT_SNAPSHOT_STALE: &str = "AUDIT_SNAPSHOT_STALE";
/// A branch head, tag target, or commit parent does not resolve to a
/// live commit.
pub const AUDIT_REF_RESOLVE: &str = "AUDIT_REF_RESOLVE";
/// A commit's table maps to a snapshot that does not exist.
pub const AUDIT_COMMIT_SNAPSHOT: &str = "AUDIT_COMMIT_SNAPSHOT";
/// A live snapshot references an object missing from the store.
pub const AUDIT_MISSING_OBJECT: &str = "AUDIT_MISSING_OBJECT";
/// A stored object is referenced by no live snapshot (info — GC owns
/// reclamation, and a crash between object PUT and journal append
/// legitimately orphans bytes).
pub const AUDIT_ORPHAN_OBJECT: &str = "AUDIT_ORPHAN_OBJECT";
/// Deep only: a stored object's bytes no longer hash to the content
/// address they are filed under.
pub const AUDIT_OBJECT_HASH: &str = "AUDIT_OBJECT_HASH";
/// Deep only: a BPB2 object's zone-map footer is unreadable or disagrees
/// with stats recomputed from the decoded body.
pub const AUDIT_ZONEMAP_STATS: &str = "AUDIT_ZONEMAP_STATS";
/// A cache-index line is unparsable or out of sequence (warn — the cache
/// self-repairs on next open, but silently losing entries is worth eyes).
pub const AUDIT_CACHE_INDEX: &str = "AUDIT_CACHE_INDEX";
/// A surviving cache entry memoizes a snapshot that no longer exists
/// (info — legal in crash/GC windows; verified-before-reuse makes it
/// harmless).
pub const AUDIT_CACHE_ENTRY: &str = "AUDIT_CACHE_ENTRY";
/// A journaled run trace has no matching run record (info).
pub const AUDIT_TRACE_ORPHAN: &str = "AUDIT_TRACE_ORPHAN";
/// A pre-segmented legacy `journal.jsonl` is still awaiting migration
/// (info — the next `Catalog::recover` consumes it).
pub const AUDIT_LEGACY_JOURNAL: &str = "AUDIT_LEGACY_JOURNAL";
/// A file the audit needed could not be read (warn offline; skipped
/// silently online where concurrent GC/compaction legally unlinks files).
pub const AUDIT_IO: &str = "AUDIT_IO";

// ------------------------------------------------------------- findings

/// How bad a finding is.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum Severity {
    /// Expected lake noise (legal crash artifacts, GC-owned orphans).
    Info,
    /// Suspicious but recoverable; does not fail `clean()` callers alone —
    /// but `FsckReport::clean` treats warnings as unclean.
    Warn,
    /// An invariant the docs promise is broken.
    Error,
}

impl Severity {
    /// Stable wire name (`"info" | "warn" | "error"`).
    pub fn as_str(self) -> &'static str {
        match self {
            Severity::Info => "info",
            Severity::Warn => "warn",
            Severity::Error => "error",
        }
    }
}

/// One integrity finding: a stable code, a severity, the lake-relative
/// file (or logical location like `refs/<name>`) it indicts, an optional
/// byte offset, and a human detail line.
#[derive(Debug, Clone)]
pub struct Finding {
    /// Stable machine-readable code (one of the `AUDIT_*` consts).
    pub code: &'static str,
    /// How bad it is.
    pub severity: Severity,
    /// Lake-relative path of the damaged file, or a logical location
    /// (`refs/main`, `commits/<id>`) for state-level findings.
    pub file: String,
    /// Byte offset of the damage inside `file`, where one exists.
    pub offset: Option<u64>,
    /// Human-readable explanation.
    pub detail: String,
}

impl Finding {
    /// Canonical JSON body.
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("code", Json::str(self.code)),
            ("severity", Json::str(self.severity.as_str())),
            ("file", Json::str(&self.file)),
            (
                "offset",
                self.offset.map(|o| Json::num(o as f64)).unwrap_or(Json::Null),
            ),
            ("detail", Json::str(&self.detail)),
        ])
    }
}

/// What the walk actually covered — the evidence behind a clean verdict.
#[derive(Debug, Clone, Copy, Default)]
pub struct FsckStats {
    /// Journal segments scanned.
    pub segments: u64,
    /// Snapshot-chain files examined (bases + deltas, stale included).
    pub snapshot_files: u64,
    /// Objects present in the store directory.
    pub objects: u64,
    /// Cache-index records parsed.
    pub cache_records: u64,
    /// Bytes read from disk over the whole walk.
    pub bytes_read: u64,
    /// Commits in the rebuilt catalog state.
    pub commits: u64,
    /// Snapshots in the rebuilt catalog state.
    pub snapshots: u64,
    /// Branches in the rebuilt catalog state.
    pub branches: u64,
}

impl FsckStats {
    /// Canonical JSON body.
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("segments", Json::num(self.segments as f64)),
            ("snapshot_files", Json::num(self.snapshot_files as f64)),
            ("objects", Json::num(self.objects as f64)),
            ("cache_records", Json::num(self.cache_records as f64)),
            ("bytes_read", Json::num(self.bytes_read as f64)),
            ("commits", Json::num(self.commits as f64)),
            ("snapshots", Json::num(self.snapshots as f64)),
            ("branches", Json::num(self.branches as f64)),
        ])
    }
}

/// Knobs for one [`fsck`] walk.
#[derive(Debug, Clone, Copy, Default)]
pub struct FsckOptions {
    /// Re-hash object bytes and cross-check BPB2 zone-map footers.
    pub deep: bool,
    /// The lake is live (the online auditor): demote cross-structure
    /// referential errors to warnings — a racing writer/GC can make them
    /// transiently true — and skip files that vanish mid-walk.
    pub online: bool,
    /// Read-rate budget in bytes/sec (0 = unthrottled). The online
    /// auditor sets this so audits never compete with the data plane.
    pub max_bytes_per_sec: u64,
}

/// The outcome of one [`fsck`] walk: findings plus coverage evidence.
#[derive(Debug, Clone)]
pub struct FsckReport {
    /// Whether the walk re-hashed objects (`--deep`).
    pub deep: bool,
    /// All findings, most severe first.
    pub findings: Vec<Finding>,
    /// Coverage evidence.
    pub stats: FsckStats,
}

impl FsckReport {
    /// Findings at exactly `severity`.
    pub fn count(&self, severity: Severity) -> u64 {
        self.findings.iter().filter(|f| f.severity == severity).count() as u64
    }

    /// No errors and no warnings. Info findings (torn active tail, GC
    /// orphans) are expected lake noise and do not fail cleanliness.
    pub fn clean(&self) -> bool {
        self.findings.iter().all(|f| f.severity == Severity::Info)
    }

    /// Canonical JSON document (served at `GET /v1/admin/fsck`, printed
    /// by `bauplan fsck --json`).
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("version", Json::num(1.0)),
            ("clean", Json::Bool(self.clean())),
            ("deep", Json::Bool(self.deep)),
            ("errors", Json::num(self.count(Severity::Error) as f64)),
            ("warnings", Json::num(self.count(Severity::Warn) as f64)),
            ("infos", Json::num(self.count(Severity::Info) as f64)),
            (
                "findings",
                Json::Arr(self.findings.iter().map(|f| f.to_json()).collect()),
            ),
            ("stats", self.stats.to_json()),
        ])
    }

    /// Human summary: one verdict line, then one line per finding.
    pub fn render(&self) -> String {
        let s = &self.stats;
        let mut out = if self.clean() {
            format!(
                "lake fsck: CLEAN{} — {} segments, {} snapshot files, {} objects, \
                 {} cache records, {} bytes read\n",
                if self.deep { " (deep)" } else { "" },
                s.segments,
                s.snapshot_files,
                s.objects,
                s.cache_records,
                s.bytes_read
            )
        } else {
            format!(
                "lake fsck: {} error(s), {} warning(s), {} info\n",
                self.count(Severity::Error),
                self.count(Severity::Warn),
                self.count(Severity::Info)
            )
        };
        for f in &self.findings {
            let at = f.offset.map(|o| format!(" @{o}")).unwrap_or_default();
            out.push_str(&format!(
                "  [{}] {} {}{}: {}\n",
                f.severity.as_str(),
                f.code,
                f.file,
                at,
                f.detail
            ));
        }
        out
    }
}

// ------------------------------------------------------------- throttle

/// Rolling one-second token bucket over bytes read.
struct Throttle {
    cap: u64,
    window: Instant,
    used: u64,
}

impl Throttle {
    fn new(cap: u64) -> Throttle {
        Throttle { cap, window: Instant::now(), used: 0 }
    }

    /// Account `bytes`; sleep out the window when over budget.
    fn charge(&mut self, bytes: u64) {
        if self.cap == 0 {
            return;
        }
        self.used += bytes;
        while self.used >= self.cap {
            let elapsed = self.window.elapsed();
            if elapsed < Duration::from_secs(1) {
                std::thread::sleep(Duration::from_secs(1) - elapsed);
            }
            self.window = Instant::now();
            self.used -= self.cap;
        }
    }
}

// ----------------------------------------------------- rebuilt state

/// Catalog state rebuilt the way recovery would, but tolerantly: parse
/// failures become findings instead of aborting the walk.
#[derive(Default)]
struct LakeState {
    commits: BTreeMap<String, Commit>,
    snapshots: BTreeMap<String, Snapshot>,
    branches: BTreeMap<String, BranchInfo>,
    tags: BTreeMap<String, String>,
    runs: BTreeMap<String, Json>,
    traces: BTreeMap<String, Json>,
}

impl LakeState {
    /// Mirror of `Catalog`'s GC mark-and-sweep: commits reachable from
    /// branch heads and tags survive, snapshots referenced by surviving
    /// commits or by the recorded pins survive. Replaying `Gc` records
    /// this way keeps the rebuilt state from indicting objects the real
    /// sweep legitimately deleted.
    fn sweep(&mut self, pins: &[String]) {
        let mut live_commits: BTreeSet<String> = BTreeSet::new();
        let mut stack: Vec<String> = self.branches.values().map(|b| b.head.clone()).collect();
        stack.extend(self.tags.values().cloned());
        while let Some(id) = stack.pop() {
            if !self.commits.contains_key(&id) || !live_commits.insert(id.clone()) {
                continue;
            }
            if let Some(c) = self.commits.get(&id) {
                stack.extend(c.parents.iter().cloned());
            }
        }
        let mut live_snaps: BTreeSet<String> = pins.iter().cloned().collect();
        for id in &live_commits {
            if let Some(c) = self.commits.get(id) {
                live_snaps.extend(c.tables.values().cloned());
            }
        }
        self.commits.retain(|id, _| live_commits.contains(id));
        self.snapshots.retain(|id, _| live_snaps.contains(id));
    }

    /// Apply one journal op — the exact semantics of
    /// `Catalog::apply_journal_record`.
    fn apply(&mut self, op: JournalOp) {
        match op {
            JournalOp::Commit { branch, commit, snapshot } => {
                if let Some(s) = snapshot {
                    self.snapshots.entry(s.id.clone()).or_insert(s);
                }
                let id = commit.id.clone();
                self.commits.insert(id.clone(), commit);
                if let Some(b) = self.branches.get_mut(&branch) {
                    b.head = id;
                }
            }
            JournalOp::Replay { branch, commits } => {
                let last = commits.last().map(|c| c.id.clone());
                for c in commits {
                    self.commits.insert(c.id.clone(), c);
                }
                if let (Some(b), Some(last)) = (self.branches.get_mut(&branch), last) {
                    b.head = last;
                }
            }
            JournalOp::BranchCreate { info } => {
                self.branches.insert(info.name.clone(), info);
            }
            JournalOp::SetBranchState { name, state } => {
                if let Some(b) = self.branches.get_mut(&name) {
                    b.state = state;
                }
            }
            JournalOp::BranchDelete { name } => {
                self.branches.remove(&name);
            }
            JournalOp::Tag { name, target } => {
                self.tags.insert(name, target);
            }
            JournalOp::Head { branch, commit } => {
                if let Some(b) = self.branches.get_mut(&branch) {
                    b.head = commit;
                }
            }
            JournalOp::RegisterSnapshot { snapshot } => {
                self.snapshots.entry(snapshot.id.clone()).or_insert(snapshot);
            }
            JournalOp::Gc { pins } => self.sweep(&pins),
            JournalOp::RunRecord { run_id, record } => {
                self.runs.insert(run_id, record);
            }
            JournalOp::RunTrace { run_id, trace } => {
                self.traces.insert(run_id, trace);
            }
        }
    }
}

// ------------------------------------------------------------ the walk

/// What the snapshot-chain walk established.
struct ChainView {
    /// Journal sequence the chain covers (0 = nothing).
    cover: u64,
    /// The base export, if one parsed.
    base_state: Option<Json>,
    /// In-chain delta documents, chain order.
    deltas: Vec<Json>,
}

struct Walker<'a> {
    dir: &'a Path,
    opts: FsckOptions,
    findings: Vec<Finding>,
    stats: FsckStats,
    throttle: Throttle,
}

impl<'a> Walker<'a> {
    fn rel(&self, p: &Path) -> String {
        p.strip_prefix(self.dir).unwrap_or(p).display().to_string()
    }

    fn push(
        &mut self,
        code: &'static str,
        severity: Severity,
        file: String,
        offset: Option<u64>,
        detail: String,
    ) {
        // Online, cross-structure referential checks race live writers,
        // GC, and compaction: what fsck observes across two reads can be
        // transiently inconsistent even though every individual write is
        // atomic. Demote those errors so only structural corruption
        // (frozen-segment damage, bad hashes) pages anyone.
        let demotable = matches!(
            code,
            AUDIT_REF_RESOLVE
                | AUDIT_COMMIT_SNAPSHOT
                | AUDIT_MISSING_OBJECT
                | AUDIT_SEGMENT_GAP
                | AUDIT_CHECKPOINT_CHAIN
        );
        let (severity, detail) = if self.opts.online && severity == Severity::Error && demotable {
            (Severity::Warn, format!("(online; may be a live-writer race) {detail}"))
        } else {
            (severity, detail)
        };
        self.findings.push(Finding { code, severity, file, offset, detail });
    }

    /// Read a whole file, charging the throttle and byte stats. Offline,
    /// an unreadable file is a warn finding; online a vanished file is a
    /// legal GC/compaction race and is skipped silently.
    fn read_file(&mut self, path: &Path) -> Option<Vec<u8>> {
        match std::fs::read(path) {
            Ok(bytes) => {
                self.stats.bytes_read += bytes.len() as u64;
                self.throttle.charge(bytes.len() as u64);
                Some(bytes)
            }
            Err(e) => {
                let vanished = e.kind() == std::io::ErrorKind::NotFound;
                if !(self.opts.online && vanished) {
                    let file = self.rel(path);
                    self.push(AUDIT_IO, Severity::Warn, file, None, format!("unreadable: {e}"));
                }
                None
            }
        }
    }

    /// Sorted names of the plain files under `dir/sub` (empty when the
    /// directory does not exist).
    fn list(&mut self, sub: &str) -> Vec<String> {
        let dir = self.dir.join(sub);
        let mut names = Vec::new();
        let entries = match std::fs::read_dir(&dir) {
            Ok(e) => e,
            Err(_) => return names,
        };
        for entry in entries.flatten() {
            if entry.file_type().map(|t| t.is_file()).unwrap_or(false) {
                if let Ok(name) = entry.file_name().into_string() {
                    names.push(name);
                }
            }
        }
        names.sort();
        names
    }

    // -------------------------------------------------- snapshot chain

    fn check_snapshot_chain(&mut self) -> ChainView {
        let mut bases: Vec<(u64, String)> = Vec::new();
        let mut deltas: Vec<(u64, u64, String)> = Vec::new();
        for name in self.list(SNAPSHOT_DIR) {
            if name.ends_with(".tmp") {
                continue; // interrupted atomic write; never observed by readers
            }
            if let Some(seq) = parse_base_name(&name) {
                bases.push((seq, name));
            } else if let Some((from, to)) = parse_delta_name(&name) {
                deltas.push((from, to, name));
            }
        }
        bases.sort();
        deltas.sort();
        self.stats.snapshot_files = (bases.len() + deltas.len()) as u64;

        let mut cover = 0u64;
        let mut base_state: Option<Json> = None;
        if let Some((seq, name)) = bases.last().cloned() {
            let path = self.dir.join(SNAPSHOT_DIR).join(&name);
            let file = self.rel(&path);
            match self.parse_json_file(&path) {
                Some(v) if v.get("state").as_obj().is_some() => {
                    cover = seq;
                    base_state = Some(v.get("state").clone());
                }
                Some(_) => {
                    self.push(
                        AUDIT_CHECKPOINT_PARSE,
                        Severity::Error,
                        file,
                        None,
                        "base snapshot is missing its state export".into(),
                    );
                }
                None => {
                    self.push(
                        AUDIT_CHECKPOINT_PARSE,
                        Severity::Error,
                        file,
                        None,
                        "base snapshot does not parse".into(),
                    );
                }
            }
        }
        // Stale bases: superseded, kept only until compaction retires
        // them — corruption there cannot hurt recovery, so warn.
        let stale_bases: Vec<String> =
            bases.iter().rev().skip(1).map(|(_, n)| n.clone()).collect();
        for name in stale_bases {
            let path = self.dir.join(SNAPSHOT_DIR).join(&name);
            if self.parse_json_file(&path).is_none() {
                let file = self.rel(&path);
                self.push(
                    AUDIT_SNAPSHOT_STALE,
                    Severity::Warn,
                    file,
                    None,
                    "stale base snapshot does not parse".into(),
                );
            }
        }

        // Legacy layout: a lake checkpointed before segmentation keeps the
        // full export in catalog.json + checkpoint.json at the root.
        if base_state.is_none() && self.dir.join("catalog.json").exists() {
            let path = self.dir.join("catalog.json");
            match self.parse_json_file(&path) {
                Some(v) => {
                    base_state = Some(v);
                    cover = read_checkpoint_seq(self.dir).unwrap_or(0);
                }
                None => {
                    let file = self.rel(&path);
                    self.push(
                        AUDIT_CHECKPOINT_PARSE,
                        Severity::Error,
                        file,
                        None,
                        "legacy checkpoint does not parse".into(),
                    );
                }
            }
        }

        let mut chained: Vec<Json> = Vec::new();
        let mut broken = false;
        for (from, to, name) in deltas {
            let path = self.dir.join(SNAPSHOT_DIR).join(&name);
            let file = self.rel(&path);
            if to <= cover {
                // Stale: already folded into the base; corruption is
                // tolerable until retirement.
                if self.parse_json_file(&path).is_none() {
                    self.push(
                        AUDIT_SNAPSHOT_STALE,
                        Severity::Warn,
                        file,
                        None,
                        "stale delta snapshot does not parse".into(),
                    );
                }
                continue;
            }
            if broken || from != cover {
                self.push(
                    AUDIT_SNAPSHOT_STALE,
                    Severity::Warn,
                    file,
                    None,
                    format!("delta does not chain onto cover {cover}"),
                );
                continue;
            }
            match self.parse_json_file(&path) {
                Some(v) => {
                    cover = to;
                    chained.push(v);
                }
                None => {
                    self.push(
                        AUDIT_CHECKPOINT_PARSE,
                        Severity::Error,
                        file,
                        None,
                        "in-chain delta snapshot does not parse".into(),
                    );
                    broken = true;
                }
            }
        }
        ChainView { cover, base_state, deltas: chained }
    }

    fn parse_json_file(&mut self, path: &Path) -> Option<Json> {
        let bytes = self.read_file(path)?;
        let text = String::from_utf8(bytes).ok()?;
        Json::parse(&text).ok()
    }

    // --------------------------------------------------------- journal

    /// Scan every journal segment, returning the records keyed by
    /// sequence number.
    fn check_journal(&mut self, cover: u64) -> BTreeMap<u64, JournalOp> {
        let mut records: BTreeMap<u64, JournalOp> = BTreeMap::new();

        // Legacy single-file journal: consumed by migration on the next
        // recover; its lines are plain records with no header/seal.
        let legacy = self.dir.join(JOURNAL_FILE);
        if legacy.exists() {
            let file = self.rel(&legacy);
            self.push(
                AUDIT_LEGACY_JOURNAL,
                Severity::Info,
                file,
                None,
                "pre-segmented journal awaiting migration".into(),
            );
            if let Some(bytes) = self.read_file(&legacy) {
                self.scan_lines(&legacy, &bytes, None, false, &mut records);
            }
        }

        let mut segs: Vec<(u64, String)> = Vec::new();
        for name in self.list(JOURNAL_DIR) {
            if let Some(first) = parse_segment_name(&name) {
                segs.push((first, name));
            }
        }
        segs.sort();
        self.stats.segments = segs.len() as u64;
        let active_first = segs.last().map(|(f, _)| *f);
        for (first, name) in segs {
            let path = self.dir.join(JOURNAL_DIR).join(&name);
            let frozen = Some(first) != active_first;
            if let Some(bytes) = self.read_file(&path) {
                self.scan_lines(&path, &bytes, Some(first), frozen, &mut records);
            }
        }

        // Contiguity above the cover: recovery replays (cover, max] and
        // needs every sequence in that range.
        if let Some(&max) = records.keys().max() {
            let mut missing_from: Option<u64> = None;
            let mut reported = 0;
            for seq in cover + 1..=max {
                let missing = !records.contains_key(&seq);
                if missing && missing_from.is_none() {
                    missing_from = Some(seq);
                }
                if (!missing || seq == max) && missing_from.is_some() {
                    let from = missing_from.take().unwrap();
                    let to = if missing { seq } else { seq - 1 };
                    let (code, what) = if from == cover + 1 {
                        (AUDIT_CHECKPOINT_CHAIN, "journal does not chain onto checkpoint cover")
                    } else {
                        (AUDIT_SEGMENT_GAP, "journal sequence gap")
                    };
                    if reported < 5 {
                        self.push(
                            code,
                            Severity::Error,
                            JOURNAL_DIR.to_string(),
                            None,
                            format!("{what}: records {from}..={to} missing (cover {cover})"),
                        );
                    }
                    reported += 1;
                }
            }
            if reported > 5 {
                self.push(
                    AUDIT_SEGMENT_GAP,
                    Severity::Error,
                    JOURNAL_DIR.to_string(),
                    None,
                    format!("{} further sequence gaps suppressed", reported - 5),
                );
            }
        }
        records
    }

    /// Scan one segment (or the legacy journal when `first_seq` is None)
    /// line by line, collecting valid records and reporting damage at its
    /// byte offset. Frozen segments must be fully valid and sealed; the
    /// active segment contributes its longest valid prefix and a torn
    /// tail is only informational.
    fn scan_lines(
        &mut self,
        path: &Path,
        bytes: &[u8],
        first_seq: Option<u64>,
        frozen: bool,
        records: &mut BTreeMap<u64, JournalOp>,
    ) {
        let file = self.rel(path);
        let mut offset = 0u64;
        let mut expect_header = first_seq.is_some();
        let mut next_seq = first_seq;
        let mut sealed_at: Option<u64> = None;
        let mut last_rec: Option<u64> = None;

        let mut torn = |w: &mut Self, off: u64, why: String| {
            if frozen {
                w.push(AUDIT_SEGMENT_CRC, Severity::Error, file.clone(), Some(off), why);
            } else {
                w.push(
                    AUDIT_SEGMENT_TORN,
                    Severity::Info,
                    file.clone(),
                    Some(off),
                    format!("torn tail (legal crash artifact): {why}"),
                );
            }
        };

        for raw in bytes.split_inclusive(|&b| b == b'\n') {
            let line_start = offset;
            offset += raw.len() as u64;
            let complete = raw.last() == Some(&b'\n');
            let line = if complete { &raw[..raw.len() - 1] } else { raw };
            if line.is_empty() {
                continue;
            }
            if !complete {
                torn(self, line_start, "incomplete final line".into());
                return;
            }
            let text = match std::str::from_utf8(line) {
                Ok(t) => t,
                Err(_) => {
                    torn(self, line_start, "line is not valid UTF-8".into());
                    return;
                }
            };
            let parsed = match parse_seg_line(text) {
                Ok(p) => p,
                Err(e) => {
                    torn(self, line_start, format!("unparsable line or crc mismatch: {e}"));
                    return;
                }
            };
            match parsed {
                SegLine::Header { first_seq: h } => {
                    if !expect_header || Some(h) != first_seq {
                        torn(self, line_start, "misplaced or mismatched header".into());
                        return;
                    }
                    expect_header = false;
                }
                SegLine::Record(rec) => {
                    if expect_header {
                        torn(self, line_start, "record before header".into());
                        return;
                    }
                    if sealed_at.is_some() {
                        torn(self, line_start, "record after seal".into());
                        return;
                    }
                    if let Some(expected) = next_seq {
                        if rec.seq != expected {
                            if frozen {
                                self.push(
                                    AUDIT_SEGMENT_GAP,
                                    Severity::Error,
                                    file.clone(),
                                    Some(line_start),
                                    format!("sequence break: got {}, expected {expected}", rec.seq),
                                );
                            } else {
                                torn(self, line_start, "sequence break".into());
                            }
                            return;
                        }
                    }
                    next_seq = Some(rec.seq + 1);
                    last_rec = Some(rec.seq);
                    records.entry(rec.seq).or_insert(rec.op);
                }
                SegLine::Seal { last_seq } => {
                    if expect_header || sealed_at.is_some() {
                        torn(self, line_start, "misplaced seal".into());
                        return;
                    }
                    let closes = last_rec.or(first_seq.map(|f| f.wrapping_sub(1)));
                    if Some(last_seq) != closes {
                        if frozen {
                            self.push(
                                AUDIT_SEGMENT_SEAL,
                                Severity::Error,
                                file.clone(),
                                Some(line_start),
                                format!("seal names {last_seq}, records end at {closes:?}"),
                            );
                        } else {
                            torn(self, line_start, "mismatched seal".into());
                        }
                        return;
                    }
                    sealed_at = Some(line_start);
                }
            }
        }
        if frozen {
            if expect_header {
                self.push(
                    AUDIT_SEGMENT_CRC,
                    Severity::Error,
                    file,
                    Some(0),
                    "missing header".into(),
                );
            } else if sealed_at.is_none() {
                self.push(
                    AUDIT_SEGMENT_SEAL,
                    Severity::Error,
                    file,
                    None,
                    "frozen segment is unsealed".into(),
                );
            }
        }
    }

    // ----------------------------------------------------------- state

    fn rebuild_state(&mut self, chain: ChainView, records: BTreeMap<u64, JournalOp>) -> LakeState {
        let mut state = LakeState::default();
        if let Some(export) = &chain.base_state {
            self.apply_export(&mut state, export);
        }
        for delta in &chain.deltas {
            self.apply_upserts(&mut state, delta.get("upserts"));
            if let Some(deleted) = delta.get("branches_deleted").as_arr() {
                for name in deleted {
                    if let Some(name) = name.as_str() {
                        state.branches.remove(name);
                    }
                }
            }
        }
        for (seq, op) in records {
            if seq > chain.cover {
                state.apply(op);
            }
        }
        self.stats.commits = state.commits.len() as u64;
        self.stats.snapshots = state.snapshots.len() as u64;
        self.stats.branches = state.branches.len() as u64;
        state
    }

    fn apply_export(&mut self, state: &mut LakeState, export: &Json) {
        self.apply_upserts(state, export);
    }

    /// Apply one export-shaped document (a full base `state` or a delta's
    /// `upserts`) — both use the same section codecs.
    fn apply_upserts(&mut self, state: &mut LakeState, doc: &Json) {
        if let Some(commits) = doc.get("commits").as_obj() {
            for (id, body) in commits {
                state.commits.insert(id.clone(), commit_from_json(id, body));
            }
        }
        if let Some(snaps) = doc.get("snapshots").as_obj() {
            for (id, body) in snaps {
                state.snapshots.insert(id.clone(), snapshot_from_json(id, body));
            }
        }
        if let Some(branches) = doc.get("branches").as_obj() {
            for (name, body) in branches {
                match branch_from_json(name, body) {
                    Ok(info) => {
                        state.branches.insert(name.clone(), info);
                    }
                    Err(e) => {
                        self.push(
                            AUDIT_CHECKPOINT_PARSE,
                            Severity::Error,
                            format!("refs/{name}"),
                            None,
                            format!("branch body does not parse: {e}"),
                        );
                    }
                }
            }
        }
        if let Some(tags) = doc.get("tags").as_obj() {
            for (name, target) in tags {
                if let Some(t) = target.as_str() {
                    state.tags.insert(name.clone(), t.to_string());
                }
            }
        }
        if let Some(runs) = doc.get("runs").as_obj() {
            for (id, body) in runs {
                state.runs.insert(id.clone(), body.clone());
            }
        }
        if let Some(traces) = doc.get("traces").as_obj() {
            for (id, body) in traces {
                state.traces.insert(id.clone(), body.clone());
            }
        }
    }

    // ------------------------------------------------------------ refs

    fn check_refs(&mut self, state: &LakeState) {
        let mut roots: Vec<(String, String)> = Vec::new(); // (where, commit)
        for (name, b) in &state.branches {
            if b.head.is_empty() || !state.commits.contains_key(&b.head) {
                self.push(
                    AUDIT_REF_RESOLVE,
                    Severity::Error,
                    format!("refs/{name}"),
                    None,
                    format!("branch head '{}' does not resolve to a commit", b.head),
                );
            } else {
                roots.push((format!("refs/{name}"), b.head.clone()));
            }
        }
        for (name, target) in &state.tags {
            if !state.commits.contains_key(target) {
                self.push(
                    AUDIT_REF_RESOLVE,
                    Severity::Error,
                    format!("refs/tags/{name}"),
                    None,
                    format!("tag target '{target}' does not resolve to a commit"),
                );
            } else {
                roots.push((format!("refs/tags/{name}"), target.clone()));
            }
        }
        // Parent closure from every resolvable root.
        let mut seen: HashSet<String> = HashSet::new();
        let mut reported: HashSet<String> = HashSet::new();
        let mut stack: Vec<String> = roots.into_iter().map(|(_, c)| c).collect();
        while let Some(id) = stack.pop() {
            if !seen.insert(id.clone()) {
                continue;
            }
            let Some(c) = state.commits.get(&id) else {
                if reported.insert(id.clone()) {
                    self.push(
                        AUDIT_REF_RESOLVE,
                        Severity::Error,
                        format!("commits/{id}"),
                        None,
                        "commit named by a parent link does not exist".into(),
                    );
                }
                continue;
            };
            stack.extend(c.parents.iter().cloned());
        }
        // Every commit's tables must map to live snapshots. All commits
        // are checked (not just reachable ones): the sweep removes a
        // commit and its snapshots together, so a dangling mapping is
        // corruption, never GC residue.
        for (id, c) in &state.commits {
            for (table, snap) in &c.tables {
                if !state.snapshots.contains_key(snap) {
                    self.push(
                        AUDIT_COMMIT_SNAPSHOT,
                        Severity::Error,
                        format!("commits/{id}"),
                        None,
                        format!("table '{table}' maps to missing snapshot '{snap}'"),
                    );
                }
            }
        }
    }

    // --------------------------------------------------------- objects

    fn check_objects(&mut self, state: &LakeState) {
        let present: BTreeSet<String> = self.list("objects").into_iter().collect();
        self.stats.objects = present.len() as u64;

        let mut live: BTreeSet<&str> = BTreeSet::new();
        for (id, s) in &state.snapshots {
            for key in &s.objects {
                if !valid_object_key(key) {
                    self.push(
                        AUDIT_MISSING_OBJECT,
                        Severity::Error,
                        format!("snapshots/{id}"),
                        None,
                        format!("snapshot references invalid object key '{key}'"),
                    );
                    continue;
                }
                live.insert(key.as_str());
                if !present.contains(key.as_str()) {
                    self.push(
                        AUDIT_MISSING_OBJECT,
                        Severity::Error,
                        format!("objects/{key}"),
                        None,
                        format!("object referenced by snapshot '{id}' is missing"),
                    );
                }
            }
        }

        let mut orphans = 0u64;
        for key in &present {
            if valid_object_key(key) && !live.contains(key.as_str()) {
                orphans += 1;
                if orphans <= 25 {
                    self.push(
                        AUDIT_ORPHAN_OBJECT,
                        Severity::Info,
                        format!("objects/{key}"),
                        None,
                        "object referenced by no live snapshot (GC owns it)".into(),
                    );
                }
            }
        }
        if orphans > 25 {
            self.push(
                AUDIT_ORPHAN_OBJECT,
                Severity::Info,
                "objects".into(),
                None,
                format!("{} further orphan objects suppressed", orphans - 25),
            );
        }

        if self.opts.deep {
            let keys: Vec<String> =
                present.iter().filter(|k| valid_object_key(k)).cloned().collect();
            for key in keys {
                self.deep_check_object(&key);
            }
        }
    }

    /// Deep object verification: content address and, for BPB2 batches,
    /// the zone-map footer against stats recomputed from the body.
    fn deep_check_object(&mut self, key: &str) {
        let path = self.dir.join("objects").join(key);
        let Some(bytes) = self.read_file(&path) else {
            return;
        };
        let file = self.rel(&path);
        if content_hash(&bytes) != key {
            self.push(
                AUDIT_OBJECT_HASH,
                Severity::Error,
                file.clone(),
                None,
                "object bytes no longer hash to their content address".into(),
            );
        }
        // BPB2 batches carry a zone-map footer; shallow scans trust it,
        // so deep mode is the only place a lying footer can be caught.
        if bytes.len() >= 4 && &bytes[..4] == b"BPB2" {
            let footer = decode_stats(&bytes);
            if footer.is_none() {
                self.push(
                    AUDIT_ZONEMAP_STATS,
                    Severity::Error,
                    file,
                    None,
                    "zone-map footer is unreadable".into(),
                );
                return;
            }
            match decode_batch(&bytes) {
                Ok(batch) => {
                    if footer != Some(compute_stats(&batch)) {
                        self.push(
                            AUDIT_ZONEMAP_STATS,
                            Severity::Error,
                            file,
                            None,
                            "zone-map footer disagrees with recomputed stats".into(),
                        );
                    }
                }
                Err(e) => {
                    self.push(
                        AUDIT_ZONEMAP_STATS,
                        Severity::Error,
                        file,
                        None,
                        format!("batch does not decode: {e}"),
                    );
                }
            }
        }
    }

    // ----------------------------------------------------------- cache

    fn check_cache(&mut self, state: &LakeState) {
        let path = self.dir.join(CACHE_INDEX_FILE);
        if !path.exists() {
            return;
        }
        let Some(bytes) = self.read_file(&path) else {
            return;
        };
        let file = self.rel(&path);
        let mut entries: BTreeMap<String, String> = BTreeMap::new();
        let mut expected = 1u64;
        let mut offset = 0u64;
        for raw in bytes.split_inclusive(|&b| b == b'\n') {
            let line_start = offset;
            offset += raw.len() as u64;
            if raw.last() != Some(&b'\n') {
                // Torn tail: the index self-repairs on next open.
                break;
            }
            let line = &raw[..raw.len() - 1];
            if line.is_empty() {
                continue;
            }
            let rec = std::str::from_utf8(line).ok().and_then(|t| IndexRecord::from_line(t).ok());
            let Some(rec) = rec else {
                self.push(
                    AUDIT_CACHE_INDEX,
                    Severity::Warn,
                    file.clone(),
                    Some(line_start),
                    "unparsable cache-index line (entries after it are lost)".into(),
                );
                break;
            };
            if rec.seq != expected {
                self.push(
                    AUDIT_CACHE_INDEX,
                    Severity::Warn,
                    file.clone(),
                    Some(line_start),
                    format!("sequence break: got {}, expected {expected}", rec.seq),
                );
                break;
            }
            expected += 1;
            self.stats.cache_records += 1;
            match rec.op {
                IndexOp::Put { key, snapshot_id, .. } => {
                    entries.insert(key, snapshot_id);
                }
                IndexOp::Hit { .. } => {}
                IndexOp::Remove { key } => {
                    entries.remove(&key);
                }
                IndexOp::Clear => entries.clear(),
            }
        }
        for (key, snap) in entries {
            if !state.snapshots.contains_key(&snap) {
                self.push(
                    AUDIT_CACHE_ENTRY,
                    Severity::Info,
                    file.clone(),
                    None,
                    format!("entry '{key}' memoizes missing snapshot '{snap}'"),
                );
            }
        }
    }

    // ------------------------------------------------------------ runs

    fn check_runs(&mut self, state: &LakeState) {
        for id in state.traces.keys() {
            if !state.runs.contains_key(id) {
                self.push(
                    AUDIT_TRACE_ORPHAN,
                    Severity::Info,
                    format!("runs/{id}"),
                    None,
                    "journaled trace has no matching run record".into(),
                );
            }
        }
    }
}

/// Walk the lake at `dir` read-only and verify every cross-structure
/// invariant. Returns a report; errors only when `dir` itself is not a
/// directory. Per-file damage becomes findings, never an `Err`.
pub fn fsck(dir: &Path, opts: &FsckOptions) -> Result<FsckReport> {
    if !dir.is_dir() {
        return Err(BauplanError::Io(std::io::Error::new(
            std::io::ErrorKind::NotFound,
            format!("lake directory not found: {}", dir.display()),
        )));
    }
    let mut w = Walker {
        dir,
        opts: *opts,
        findings: Vec::new(),
        stats: FsckStats::default(),
        throttle: Throttle::new(opts.max_bytes_per_sec),
    };
    let chain = w.check_snapshot_chain();
    let records = w.check_journal(chain.cover);
    let state = w.rebuild_state(chain, records);
    w.check_refs(&state);
    w.check_objects(&state);
    w.check_cache(&state);
    w.check_runs(&state);
    let mut findings = w.findings;
    findings.sort_by(|a, b| {
        b.severity.cmp(&a.severity).then_with(|| a.file.cmp(&b.file)).then(a.offset.cmp(&b.offset))
    });
    Ok(FsckReport { deep: opts.deep, findings, stats: w.stats })
}

/// Convenience used by the CLI, the sim oracle, and the crash matrix:
/// path in, default (shallow, offline, unthrottled) options.
pub fn fsck_path(dir: impl AsRef<Path>, deep: bool) -> Result<FsckReport> {
    fsck(dir.as_ref(), &FsckOptions { deep, ..FsckOptions::default() })
}

/// The lake-relative file a report's worst finding indicts, with its
/// code — the one-line story for flight dumps and violation details.
pub fn worst_finding(report: &FsckReport) -> Option<(String, String)> {
    report
        .findings
        .iter()
        .filter(|f| f.severity >= Severity::Warn)
        .max_by_key(|f| f.severity)
        .map(|f| (f.code.to_string(), format!("{} {}: {}", f.code, f.file, f.detail)))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::catalog::{Catalog, CommitRequest, JournalConfig, Snapshot};
    use std::path::PathBuf;
    use std::sync::atomic::{AtomicU64, Ordering};

    fn tmp(tag: &str) -> PathBuf {
        static SEQ: AtomicU64 = AtomicU64::new(0);
        let n = SEQ.fetch_add(1, Ordering::Relaxed);
        let dir = std::env::temp_dir()
            .join(format!("bauplan-audit-{tag}-{}-{n}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        dir
    }

    #[test]
    fn missing_dir_is_an_error() {
        let dir = std::env::temp_dir().join("bauplan-audit-definitely-missing");
        assert!(fsck(&dir, &FsckOptions::default()).is_err());
    }

    #[test]
    fn empty_lake_is_clean() {
        let dir = tmp("empty");
        let report = fsck(&dir, &FsckOptions::default()).unwrap();
        assert!(report.clean(), "{}", report.render());
        assert_eq!(report.stats.segments, 0);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn fresh_durable_lake_is_clean_and_walk_is_read_only() {
        let dir = tmp("fresh");
        {
            let cat = Catalog::recover(&dir).unwrap();
            let data = cat.store().put(b"hello audit".to_vec());
            let snap = Snapshot::new(vec![data], "S", "fp", 1, "rw");
            cat.commit(CommitRequest::new("main", "t", snap)).unwrap();
            cat.checkpoint().unwrap();
        }
        let before = dir_digest(&dir);
        let report = fsck(&dir, &FsckOptions { deep: true, ..Default::default() }).unwrap();
        assert!(report.clean(), "{}", report.render());
        assert!(report.stats.segments >= 1);
        assert!(report.stats.objects >= 1);
        assert_eq!(before, dir_digest(&dir), "fsck must not write to the lake");
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn corrupt_frozen_segment_is_named() {
        let dir = tmp("corrupt");
        {
            let cfg = JournalConfig { segment_bytes: 256, ..JournalConfig::default() };
            let cat = Catalog::open_durable_cfg(&dir, cfg).unwrap();
            for i in 0..8 {
                let data = cat.store().put(format!("payload {i}").into_bytes());
                let snap = Snapshot::new(vec![data], "S", "fp", 1, "rw");
                cat.commit(CommitRequest::new("main", &format!("t{i}"), snap)).unwrap();
            }
        }
        // Flip one byte mid-line in the oldest (frozen) segment.
        let seg_dir = dir.join(JOURNAL_DIR);
        let mut names: Vec<_> = std::fs::read_dir(&seg_dir)
            .unwrap()
            .flatten()
            .map(|e| e.file_name().into_string().unwrap())
            .collect();
        names.sort();
        assert!(names.len() >= 2, "need a frozen segment; got {names:?}");
        let victim = seg_dir.join(&names[0]);
        let mut bytes = std::fs::read(&victim).unwrap();
        let mid = bytes.len() / 2;
        bytes[mid] ^= 0x01;
        std::fs::write(&victim, &bytes).unwrap();

        let report = fsck(&dir, &FsckOptions::default()).unwrap();
        assert!(!report.clean());
        let hit = report
            .findings
            .iter()
            .find(|f| f.severity == Severity::Error && f.file.ends_with(&names[0]))
            .unwrap_or_else(|| panic!("no error names {}: {}", names[0], report.render()));
        assert!(
            hit.code == AUDIT_SEGMENT_CRC
                || hit.code == AUDIT_SEGMENT_GAP
                || hit.code == AUDIT_SEGMENT_SEAL,
            "unexpected code {}",
            hit.code
        );
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn report_json_shape_is_stable() {
        let report = FsckReport {
            deep: false,
            findings: vec![Finding {
                code: AUDIT_SEGMENT_CRC,
                severity: Severity::Error,
                file: "journal/seg-x.jsonl".into(),
                offset: Some(42),
                detail: "boom".into(),
            }],
            stats: FsckStats::default(),
        };
        let j = report.to_json();
        assert_eq!(j.get("clean").as_bool(), Some(false));
        assert_eq!(j.get("errors").as_f64(), Some(1.0));
        let f = &j.get("findings").as_arr().unwrap()[0];
        assert_eq!(f.get("code").as_str(), Some(AUDIT_SEGMENT_CRC));
        assert_eq!(f.get("offset").as_f64(), Some(42.0));
        assert!(report.render().contains("AUDIT_SEGMENT_CRC"));
    }

    #[test]
    fn online_mode_demotes_referential_errors() {
        let dir = tmp("demote");
        {
            let cat = Catalog::recover(&dir).unwrap();
            let data = cat.store().put(b"x".to_vec());
            let snap = Snapshot::new(vec![data.clone()], "S", "fp", 1, "rw");
            cat.commit(CommitRequest::new("main", "t", snap)).unwrap();
            // Simulate the GC race: the object vanishes out from under a
            // live snapshot.
            std::fs::remove_file(dir.join("objects").join(&data)).unwrap();
        }
        let offline = fsck(&dir, &FsckOptions::default()).unwrap();
        assert!(offline.findings.iter().any(|f| f.code == AUDIT_MISSING_OBJECT
            && f.severity == Severity::Error));
        let online =
            fsck(&dir, &FsckOptions { online: true, ..Default::default() }).unwrap();
        assert!(online.findings.iter().any(|f| f.code == AUDIT_MISSING_OBJECT
            && f.severity == Severity::Warn));
        let _ = std::fs::remove_dir_all(&dir);
    }

    /// Recursive (path, size, mtime-free) digest of a directory tree —
    /// mtimes excluded so reading files does not register.
    fn dir_digest(dir: &Path) -> Vec<(String, u64, Vec<u8>)> {
        let mut out = Vec::new();
        let mut stack = vec![dir.to_path_buf()];
        while let Some(d) = stack.pop() {
            for e in std::fs::read_dir(&d).unwrap().flatten() {
                let p = e.path();
                if p.is_dir() {
                    stack.push(p);
                } else {
                    let bytes = std::fs::read(&p).unwrap();
                    out.push((p.display().to_string(), bytes.len() as u64, bytes));
                }
            }
        }
        out.sort();
        out
    }
}
