//! The online integrity auditor: a budgeted background thread inside the
//! server that runs the offline [`fsck`](crate::audit::fsck) walker
//! against the live lake on a fixed cadence.
//!
//! Design constraints (doc/FSCK.md §Online budget model):
//!
//! - **Bounded interference:** every cycle reads through a bytes/sec
//!   throttle ([`AuditConfig::max_bytes_per_sec`]) so audits never
//!   compete with the data plane; `bench_fsck` gates the commit-path
//!   overhead at ≤ `BENCH_FSCK_MAX_OVERHEAD`.
//! - **Race honesty:** the walker runs with `FsckOptions::online`, which
//!   demotes cross-structure referential errors to warnings — a racing
//!   writer, GC, or compaction can make them transiently true. Only
//!   structural corruption (frozen-segment damage, bad content hashes)
//!   stays error-severity, and *that* dumps the flight recorder.
//! - **Observable:** every cycle exports `audit.*` metrics through the
//!   shared registry onto `/metrics`, and the latest report is served at
//!   `GET /v1/admin/fsck` and summarized in `GET /v1/status`.

use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use crate::audit::{fsck, worst_finding, FsckOptions, FsckReport, Severity};
use crate::metrics::Metrics;
use crate::trace::FlightRecorder;
use crate::util::json::Json;
use crate::util::now_micros;

/// Knobs for the server's background auditor.
#[derive(Debug, Clone)]
pub struct AuditConfig {
    /// Run the auditor at all (off for benches measuring its absence).
    pub enabled: bool,
    /// Idle time between the end of one cycle and the start of the next.
    pub interval: Duration,
    /// Read-rate budget per cycle in bytes/sec (0 = unthrottled).
    pub max_bytes_per_sec: u64,
    /// Re-hash object bytes and cross-check zone-map footers each cycle.
    pub deep: bool,
}

impl Default for AuditConfig {
    fn default() -> AuditConfig {
        AuditConfig {
            enabled: true,
            interval: Duration::from_secs(5),
            max_bytes_per_sec: 8 << 20,
            deep: false,
        }
    }
}

/// Auditor state shared with the API layer: the latest report and the
/// rolled-up summary `GET /v1/status` embeds.
#[derive(Debug, Default)]
pub struct AuditShared {
    last_report: Mutex<Option<Json>>,
    cycles: AtomicU64,
    last_clean_us: AtomicU64,
    last_errors: AtomicU64,
    last_warnings: AtomicU64,
    last_cycle_us: AtomicU64,
}

impl AuditShared {
    /// Completed audit cycles.
    pub fn cycles(&self) -> u64 {
        self.cycles.load(Ordering::Relaxed)
    }

    /// The latest full report as canonical JSON (None before the first
    /// cycle completes).
    pub fn last_report_json(&self) -> Option<Json> {
        self.last_report.lock().unwrap().clone()
    }

    /// The rolled-up summary embedded in `GET /v1/status`.
    pub fn summary_json(&self) -> Json {
        let clean_us = self.last_clean_us.load(Ordering::Relaxed);
        Json::obj(vec![
            ("cycles", Json::num(self.cycles() as f64)),
            (
                "last_clean_timestamp_us",
                if clean_us == 0 { Json::Null } else { Json::num(clean_us as f64) },
            ),
            ("last_errors", Json::num(self.last_errors.load(Ordering::Relaxed) as f64)),
            ("last_warnings", Json::num(self.last_warnings.load(Ordering::Relaxed) as f64)),
            ("last_cycle_us", Json::num(self.last_cycle_us.load(Ordering::Relaxed) as f64)),
        ])
    }

    fn record(&self, report: &FsckReport, cycle: Duration) {
        *self.last_report.lock().unwrap() = Some(report.to_json());
        self.cycles.fetch_add(1, Ordering::Relaxed);
        self.last_errors.store(report.count(Severity::Error), Ordering::Relaxed);
        self.last_warnings.store(report.count(Severity::Warn), Ordering::Relaxed);
        self.last_cycle_us.store(cycle.as_micros() as u64, Ordering::Relaxed);
        if report.clean() {
            self.last_clean_us.store(now_micros(), Ordering::Relaxed);
        }
    }
}

/// Handle on the spawned auditor thread; [`AuditorHandle::stop`] (or
/// drop) shuts it down and joins.
pub struct AuditorHandle {
    shutdown: Arc<AtomicBool>,
    thread: Option<JoinHandle<()>>,
    shared: Arc<AuditShared>,
}

impl AuditorHandle {
    /// Spawn the background auditor over the lake at `dir`.
    pub fn spawn(
        dir: PathBuf,
        config: AuditConfig,
        metrics: Arc<Metrics>,
        flight: FlightRecorder,
    ) -> AuditorHandle {
        let shutdown = Arc::new(AtomicBool::new(false));
        let shared = Arc::new(AuditShared::default());
        let stop = shutdown.clone();
        let shared_t = shared.clone();
        let thread = std::thread::Builder::new()
            .name("bauplan-auditor".into())
            .spawn(move || run_loop(&dir, &config, &metrics, &flight, &stop, &shared_t))
            .expect("spawn auditor thread");
        AuditorHandle { shutdown, thread: Some(thread), shared }
    }

    /// The state shared with the API layer.
    pub fn shared(&self) -> Arc<AuditShared> {
        self.shared.clone()
    }

    /// Signal shutdown and join the thread (idempotent).
    pub fn stop(&mut self) {
        self.shutdown.store(true, Ordering::SeqCst);
        if let Some(t) = self.thread.take() {
            let _ = t.join();
        }
    }
}

impl Drop for AuditorHandle {
    fn drop(&mut self) {
        self.stop();
    }
}

fn run_loop(
    dir: &std::path::Path,
    config: &AuditConfig,
    metrics: &Metrics,
    flight: &FlightRecorder,
    shutdown: &AtomicBool,
    shared: &AuditShared,
) {
    let opts = FsckOptions {
        deep: config.deep,
        online: true,
        max_bytes_per_sec: config.max_bytes_per_sec,
    };
    while !shutdown.load(Ordering::SeqCst) {
        let t0 = Instant::now();
        let mut span = flight.begin("audit.cycle");
        match fsck(dir, &opts) {
            Ok(report) => {
                let errors = report.count(Severity::Error);
                span.attr_u64("findings", report.findings.len() as u64);
                span.attr_u64("bytes_read", report.stats.bytes_read);
                metrics.incr("audit.cycles", 1);
                metrics.incr("audit.bytes_scanned", report.stats.bytes_read);
                metrics.set("audit.findings_error", errors);
                metrics.set("audit.findings_warn", report.count(Severity::Warn));
                metrics.set("audit.findings_info", report.count(Severity::Info));
                metrics
                    .histogram("audit.cycle_us")
                    .record_us(t0.elapsed().as_micros() as u64);
                shared.record(&report, t0.elapsed());
                if report.clean() {
                    metrics.set("audit.last_clean_timestamp_us", now_micros());
                }
                if errors > 0 {
                    // Error-severity findings are the flight-recorder gap
                    // this auditor closes: leave a post-mortem on disk
                    // naming the finding, like poisoning does.
                    let (code, detail) =
                        worst_finding(&report).unwrap_or_default();
                    span.fail(detail);
                    span.finish();
                    let _ = flight.dump(dir, &format!("audit {code}"));
                    // span already finished; skip the drop below
                    sleep_interval(config.interval, shutdown);
                    continue;
                }
            }
            Err(e) => {
                metrics.incr("audit.failures", 1);
                span.fail(format!("audit cycle failed: {e}"));
            }
        }
        drop(span);
        sleep_interval(config.interval, shutdown);
    }
}

/// Sleep `interval` in short slices so shutdown stays responsive.
fn sleep_interval(interval: Duration, shutdown: &AtomicBool) {
    let mut left = interval;
    while !left.is_zero() && !shutdown.load(Ordering::SeqCst) {
        let step = left.min(Duration::from_millis(25));
        std::thread::sleep(step);
        left = left.saturating_sub(step);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::path::PathBuf;

    fn tmp(tag: &str) -> PathBuf {
        use std::sync::atomic::AtomicU64;
        static SEQ: AtomicU64 = AtomicU64::new(0);
        let n = SEQ.fetch_add(1, Ordering::Relaxed);
        let dir = std::env::temp_dir()
            .join(format!("bauplan-auditor-{tag}-{}-{n}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        dir
    }

    #[test]
    fn auditor_cycles_and_exports_metrics() {
        let dir = tmp("cycles");
        {
            let cat = crate::catalog::Catalog::recover(&dir).unwrap();
            let data = cat.store().put(b"audited".to_vec());
            let snap = crate::catalog::Snapshot::new(vec![data], "S", "fp", 1, "rw");
            cat.commit(crate::catalog::CommitRequest::new("main", "t", snap)).unwrap();
        }
        let metrics = Arc::new(Metrics::new());
        let flight = FlightRecorder::new(16);
        let config = AuditConfig { interval: Duration::from_millis(10), ..Default::default() };
        let mut h = AuditorHandle::spawn(dir.clone(), config, metrics.clone(), flight);
        let deadline = Instant::now() + Duration::from_secs(10);
        while h.shared().cycles() == 0 && Instant::now() < deadline {
            std::thread::sleep(Duration::from_millis(5));
        }
        h.stop();
        assert!(h.shared().cycles() >= 1, "auditor never completed a cycle");
        assert!(metrics.counter("audit.cycles") >= 1);
        assert!(metrics.counter("audit.last_clean_timestamp_us") > 0);
        let report = h.shared().last_report_json().unwrap();
        assert_eq!(report.get("clean").as_bool(), Some(true));
        let summary = h.shared().summary_json();
        assert!(summary.get("last_clean_timestamp_us").as_f64().is_some());
        let _ = std::fs::remove_dir_all(&dir);
    }
}
