//! The durable run-cache index: an append-only, crc'd record log with
//! the same canonical-JSON line conventions as the commit journal
//! (`catalog::journal`), and the same recovery rule — the longest valid
//! prefix wins, a torn or corrupt suffix is truncated away.
//!
//! ## File format
//!
//! `cache.jsonl` lines are canonical-JSON objects
//! `{"crc":H,"data":D,"op":O,"seq":N}` where `H` is the content hash of
//! the canonical serialization of `{"data":D,"op":O,"seq":N}` and
//! sequence numbers are strictly consecutive. Ops:
//!
//! - `put`    — an entry became reusable (populate-after-verify);
//! - `hit`    — an entry was served (advances its LRU position);
//! - `remove` — an entry was evicted or found stale;
//! - `clear`  — the cache was emptied.
//!
//! The index is *advisory state*: losing a suffix (or the whole file)
//! costs recomputation, never correctness — replay of a valid prefix
//! yields a cache whose every entry was verified before its `put` was
//! appended, and attaching the cache
//! ([`Client::attach_run_cache`](crate::client::Client::attach_run_cache))
//! re-pins entries against the recovered catalog, dropping any whose
//! snapshot no longer resolves.

use std::fs::{File, OpenOptions};
use std::io::{Read, Write};
use std::path::{Path, PathBuf};

use crate::error::{BauplanError, Result};
use crate::util::id::content_hash;
use crate::util::json::Json;

/// One logged cache mutation.
#[derive(Debug, Clone, PartialEq)]
pub enum IndexOp {
    /// An entry became reusable.
    Put {
        /// The run-cache key.
        key: String,
        /// Snapshot the key memoizes.
        snapshot_id: String,
        /// Physical bytes the snapshot's objects occupy (LRU budget +
        /// bytes-saved accounting).
        bytes: u64,
        /// Logical LRU clock at insert.
        at: u64,
    },
    /// An entry was served; `at` is its new LRU position.
    Hit {
        /// The run-cache key.
        key: String,
        /// Logical LRU clock at the hit.
        at: u64,
    },
    /// An entry was evicted or invalidated.
    Remove {
        /// The run-cache key.
        key: String,
    },
    /// Every entry was dropped.
    Clear,
}

/// A sequenced index record.
#[derive(Debug, Clone, PartialEq)]
pub struct IndexRecord {
    /// Strictly increasing sequence number (1-based).
    pub seq: u64,
    /// The mutation.
    pub op: IndexOp,
}

impl IndexRecord {
    fn op_name(&self) -> &'static str {
        match &self.op {
            IndexOp::Put { .. } => "put",
            IndexOp::Hit { .. } => "hit",
            IndexOp::Remove { .. } => "remove",
            IndexOp::Clear => "clear",
        }
    }

    fn data_json(&self) -> Json {
        match &self.op {
            IndexOp::Put { key, snapshot_id, bytes, at } => Json::obj(vec![
                ("at", Json::num(*at as f64)),
                ("bytes", Json::num(*bytes as f64)),
                ("key", Json::str(key)),
                ("snapshot_id", Json::str(snapshot_id)),
            ]),
            IndexOp::Hit { key, at } => Json::obj(vec![
                ("at", Json::num(*at as f64)),
                ("key", Json::str(key)),
            ]),
            IndexOp::Remove { key } => Json::obj(vec![("key", Json::str(key))]),
            IndexOp::Clear => Json::obj(vec![]),
        }
    }

    /// Serialize to one canonical line (`\n`-terminated) — same envelope
    /// as a journal record.
    pub fn to_line(&self) -> String {
        let inner = Json::obj(vec![
            ("data", self.data_json()),
            ("op", Json::str(self.op_name())),
            ("seq", Json::num(self.seq as f64)),
        ]);
        let body = inner.to_string();
        let crc = content_hash(body.as_bytes());
        format!("{{\"crc\":\"{crc}\",{}\n", &body[1..])
    }

    /// Parse and integrity-check one line (without the trailing newline).
    pub fn from_line(line: &str) -> Result<IndexRecord> {
        let v = Json::parse(line)?;
        let crc = v
            .get("crc")
            .as_str()
            .ok_or_else(|| BauplanError::Parse("cache index record: missing crc".into()))?
            .to_string();
        let seq = v
            .get("seq")
            .as_f64()
            .ok_or_else(|| BauplanError::Parse("cache index record: missing seq".into()))?
            as u64;
        let op_name = v
            .get("op")
            .as_str()
            .ok_or_else(|| BauplanError::Parse("cache index record: missing op".into()))?
            .to_string();
        let data = v.get("data").clone();
        let inner = Json::obj(vec![
            ("data", data.clone()),
            ("op", Json::str(&op_name)),
            ("seq", Json::num(seq as f64)),
        ]);
        if content_hash(inner.to_string().as_bytes()) != crc {
            return Err(BauplanError::Parse(format!(
                "cache index record seq {seq}: crc mismatch"
            )));
        }
        let str_field = |k: &str| -> Result<String> {
            data.get(k)
                .as_str()
                .map(String::from)
                .ok_or_else(|| {
                    BauplanError::Parse(format!("cache index record: missing {k}"))
                })
        };
        let num_field = |k: &str| -> Result<u64> {
            data.get(k)
                .as_f64()
                .map(|n| n as u64)
                .ok_or_else(|| {
                    BauplanError::Parse(format!("cache index record: missing {k}"))
                })
        };
        let op = match op_name.as_str() {
            "put" => IndexOp::Put {
                key: str_field("key")?,
                snapshot_id: str_field("snapshot_id")?,
                bytes: num_field("bytes")?,
                at: num_field("at")?,
            },
            "hit" => IndexOp::Hit { key: str_field("key")?, at: num_field("at")? },
            "remove" => IndexOp::Remove { key: str_field("key")? },
            "clear" => IndexOp::Clear,
            other => {
                return Err(BauplanError::Parse(format!(
                    "cache index record: unknown op '{other}'"
                )))
            }
        };
        Ok(IndexRecord { seq, op })
    }
}

/// The append-only index file handle. Driven only under the owning
/// [`super::RunCache`]'s lock, so appends are totally ordered.
pub struct IndexLog {
    path: PathBuf,
    file: File,
    next_seq: u64,
}

impl IndexLog {
    /// Open (or create) the index at `path`, scan it, repair a torn or
    /// corrupt tail, and return the handle plus every valid record in
    /// order.
    pub fn open(path: impl Into<PathBuf>) -> Result<(IndexLog, Vec<IndexRecord>)> {
        let path = path.into();
        if let Some(parent) = path.parent() {
            if !parent.as_os_str().is_empty() {
                std::fs::create_dir_all(parent)?;
            }
        }
        // O_APPEND, not write+seek: every write lands atomically at the
        // current end of file, so a second process that also opened the
        // index (gc, cache clear) cannot clobber records this one
        // appended after the other's open. Reads still start at offset 0.
        let mut file = OpenOptions::new()
            .read(true)
            .append(true)
            .create(true)
            .open(&path)?;
        let mut bytes = Vec::new();
        file.read_to_end(&mut bytes)?;

        let (records, valid_end) = Self::parse_prefix(&bytes);
        if valid_end < bytes.len() {
            file.set_len(valid_end as u64)?;
            file.sync_data()?;
        }
        let next_seq = records.last().map(|r| r.seq).unwrap_or(0) + 1;
        Ok((IndexLog { path, file, next_seq }, records))
    }

    /// Read-only scan: the longest valid record prefix of the file at
    /// `path`, without creating, repairing, truncating, or holding a
    /// writable handle — safe to call while another process has the
    /// index open for appending. A missing file is an empty index.
    pub fn scan(path: impl AsRef<Path>) -> Result<Vec<IndexRecord>> {
        let bytes = match std::fs::read(path.as_ref()) {
            Ok(b) => b,
            Err(e) if e.kind() == std::io::ErrorKind::NotFound => return Ok(Vec::new()),
            Err(e) => return Err(e.into()),
        };
        Ok(Self::parse_prefix(&bytes).0)
    }

    /// The longest valid prefix rule shared by [`IndexLog::open`] and
    /// [`IndexLog::scan`]: returns the parsed records and the byte
    /// offset just past the last valid line.
    fn parse_prefix(bytes: &[u8]) -> (Vec<IndexRecord>, usize) {
        let mut records: Vec<IndexRecord> = Vec::new();
        let mut offset = 0usize;
        let mut valid_end = 0usize;
        while offset < bytes.len() {
            let nl = match bytes[offset..].iter().position(|&b| b == b'\n') {
                Some(rel) => offset + rel,
                None => break, // incomplete final line
            };
            let line = match std::str::from_utf8(&bytes[offset..nl]) {
                Ok(s) => s,
                Err(_) => break,
            };
            let rec = match IndexRecord::from_line(line) {
                Ok(r) => r,
                Err(_) => break, // bad json / crc / op: keep the prefix
            };
            let expected = records.last().map(|r| r.seq + 1).unwrap_or(1);
            if rec.seq != expected {
                break;
            }
            records.push(rec);
            offset = nl + 1;
            valid_end = offset;
        }
        (records, valid_end)
    }

    /// Append one op. `put`/`remove`/`clear` are fsynced before
    /// returning (entry membership survives a crash); `hit` records are
    /// not — they only carry LRU recency, whose loss is harmless by
    /// design, and the hot hit path must not pay an fsync per node. A
    /// later synced append (or clean `Drop`) flushes them.
    pub fn append(&mut self, op: IndexOp) -> Result<u64> {
        let seq = self.next_seq;
        let durable = !matches!(op, IndexOp::Hit { .. });
        let line = IndexRecord { seq, op }.to_line();
        self.file.write_all(line.as_bytes())?;
        if durable {
            self.file.sync_data()?;
        }
        self.next_seq += 1;
        Ok(seq)
    }

    /// Compact: atomically replace the file with exactly `ops`
    /// (renumbered from 1), via temp-write → fsync → rename, then
    /// reopen the handle in append mode on the new inode.
    pub fn rewrite(&mut self, ops: &[IndexOp]) -> Result<()> {
        let tmp = self.path.with_extension("jsonl.tmp");
        {
            let mut f = File::create(&tmp)?;
            for (i, op) in ops.iter().enumerate() {
                let line = IndexRecord { seq: i as u64 + 1, op: op.clone() }.to_line();
                f.write_all(line.as_bytes())?;
            }
            f.sync_data()?;
        }
        std::fs::rename(&tmp, &self.path)?;
        self.file = OpenOptions::new().read(true).append(true).open(&self.path)?;
        self.next_seq = ops.len() as u64 + 1;
        Ok(())
    }

    /// Path of the index file.
    pub fn path(&self) -> &Path {
        &self.path
    }
}

impl Drop for IndexLog {
    fn drop(&mut self) {
        // best effort: flush unsynced hit records on clean shutdown
        let _ = self.file.sync_data();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmpdir(tag: &str) -> PathBuf {
        let d = std::env::temp_dir().join(format!("bpl_cidx_{tag}_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&d);
        std::fs::create_dir_all(&d).unwrap();
        d
    }

    #[test]
    fn record_roundtrip_all_ops() {
        let ops = vec![
            IndexOp::Put {
                key: "k1".into(),
                snapshot_id: "s1".into(),
                bytes: 4096,
                at: 7,
            },
            IndexOp::Hit { key: "k1".into(), at: 8 },
            IndexOp::Remove { key: "k1".into() },
            IndexOp::Clear,
        ];
        for (i, op) in ops.into_iter().enumerate() {
            let rec = IndexRecord { seq: i as u64 + 1, op };
            let back = IndexRecord::from_line(rec.to_line().trim_end()).unwrap();
            assert_eq!(back, rec);
        }
    }

    #[test]
    fn crc_detects_tampering() {
        let rec = IndexRecord {
            seq: 1,
            op: IndexOp::Hit { key: "k".into(), at: 3 },
        };
        let tampered = rec.to_line().replace("\"at\":3", "\"at\":4");
        assert!(IndexRecord::from_line(tampered.trim_end()).is_err());
    }

    #[test]
    fn torn_tail_is_truncated_and_log_reusable() {
        let dir = tmpdir("torn");
        let path = dir.join("cache.jsonl");
        {
            let (mut log, recs) = IndexLog::open(&path).unwrap();
            assert!(recs.is_empty());
            log.append(IndexOp::Hit { key: "a".into(), at: 1 }).unwrap();
            log.append(IndexOp::Hit { key: "b".into(), at: 2 }).unwrap();
        }
        // simulate a crash mid-append: partial last line
        let mut f = OpenOptions::new().append(true).open(&path).unwrap();
        f.write_all(b"{\"crc\":\"dead").unwrap();
        drop(f);

        let (mut log, recs) = IndexLog::open(&path).unwrap();
        assert_eq!(recs.len(), 2);
        // numbering continues past the repaired prefix
        assert_eq!(log.append(IndexOp::Clear).unwrap(), 3);
        let (_, recs) = IndexLog::open(&path).unwrap();
        assert_eq!(recs.len(), 3);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn sequence_gap_discards_suffix() {
        let dir = tmpdir("gap");
        let path = dir.join("cache.jsonl");
        let r1 = IndexRecord { seq: 1, op: IndexOp::Clear };
        let r3 = IndexRecord { seq: 3, op: IndexOp::Clear };
        std::fs::write(&path, format!("{}{}", r1.to_line(), r3.to_line())).unwrap();
        let (_, recs) = IndexLog::open(&path).unwrap();
        assert_eq!(recs.len(), 1);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn rewrite_compacts_and_renumbers() {
        let dir = tmpdir("rw");
        let path = dir.join("cache.jsonl");
        let (mut log, _) = IndexLog::open(&path).unwrap();
        for i in 0..5 {
            log.append(IndexOp::Hit { key: format!("k{i}"), at: i }).unwrap();
        }
        log.rewrite(&[IndexOp::Put {
            key: "only".into(),
            snapshot_id: "s".into(),
            bytes: 1,
            at: 9,
        }])
        .unwrap();
        // appending after a rewrite continues the compacted numbering
        log.append(IndexOp::Clear).unwrap();
        let (_, recs) = IndexLog::open(&path).unwrap();
        assert_eq!(recs.len(), 2);
        assert_eq!(recs[0].seq, 1);
        assert!(matches!(recs[0].op, IndexOp::Put { .. }));
        let _ = std::fs::remove_dir_all(&dir);
    }
}
