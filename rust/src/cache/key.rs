//! Cache-key derivation: the canonical fingerprints that make memoized
//! node executions content-addressed.
//!
//! A node's result is a pure function of (paper §3.2's reproducibility
//! argument): the compute artifact, the runtime parameters, the exact
//! input table snapshots, and the output contract it was validated
//! against. The run-cache key is a hash over precisely those four
//! inputs, assembled in two stages:
//!
//! 1. **static fingerprint** — derived at *plan* time by the DAG layer
//!    ([`crate::dag::PipelineSpec::plan`]): op name, parameter bits, the
//!    output contract fingerprint, and the input contract fingerprints.
//!    Pure content, no plan-order or process state, so two specs that
//!    declare the same node in different positions (or different
//!    processes) derive identical fingerprints.
//! 2. **run key** — derived at *execution* time by the runner: the
//!    static fingerprint + the artifact fingerprint from the loaded
//!    manifest + the input snapshot ids the node actually read.
//!
//! [`contract_fingerprint`] is deliberately richer than
//! [`Schema::fingerprint`]: bounds, uniqueness, NotNull filters, and
//! lineage annotations all participate, because tightening any of them
//! changes what a "validated" snapshot means — a cached result must
//! never outlive the contract it was verified under.

use crate::contracts::schema::Schema;
use crate::util::id::{content_hash, content_hash_parts};

/// A run-cache key (hex digest).
pub type CacheKey = String;

/// Domain separator baked into every run-cache key; bump on any change
/// to the derivation so stale durable indexes self-invalidate.
const KEY_DOMAIN: &str = "bauplan.run_cache.v1";

/// Full contract fingerprint of a schema: every semantic knob of every
/// field, in declaration order. Unlike [`Schema::fingerprint`] (which
/// tracks physical drift only: name/type/nullability), this also covers
/// bounds, `[unique]`, `[NotNull]`, casts, and lineage — the inputs to
/// the M3 verdict.
pub fn contract_fingerprint(schema: &Schema) -> String {
    let mut desc = String::new();
    desc.push_str(&schema.name);
    for f in &schema.fields {
        desc.push('|');
        desc.push_str(&f.name);
        desc.push(':');
        desc.push_str(&f.ty.logical.to_string());
        desc.push(if f.ty.nullable { 'n' } else { '-' });
        match f.ty.bounds {
            // exact bit patterns: no float formatting in the identity
            Some((lo, hi)) => {
                desc.push_str(&format!(":b{:016x}:{:016x}", lo.to_bits(), hi.to_bits()))
            }
            None => desc.push_str(":b-"),
        }
        desc.push(if f.unique { 'u' } else { '-' });
        desc.push(if f.not_null_filter { 'f' } else { '-' });
        desc.push(if f.with_cast { 'c' } else { '-' });
        match &f.inherited_from {
            Some((s, c)) => desc.push_str(&format!(":{s}.{c}")),
            None => desc.push_str(":-"),
        }
    }
    content_hash(desc.as_bytes())
}

/// Plan-time half of the key: everything about a node that is knowable
/// before any data exists. Insensitive to the node's position in the
/// spec and to output/input *table names* (the data identity is carried
/// by snapshot ids at run time); sensitive to op, parameter bits, and
/// the contracts on both sides of the boundary.
pub fn node_static_fingerprint(
    op: &str,
    params: &[f32],
    out_contract_fp: &str,
    input_contract_fps: &[String],
) -> String {
    let mut parts: Vec<Vec<u8>> = Vec::with_capacity(3 + params.len() + input_contract_fps.len());
    parts.push(b"node.v1".to_vec());
    parts.push(op.as_bytes().to_vec());
    for p in params {
        // bit-exact: -0.0 vs 0.0 and NaN payloads are distinct params
        parts.push(format!("{:08x}", p.to_bits()).into_bytes());
    }
    parts.push(out_contract_fp.as_bytes().to_vec());
    for fp in input_contract_fps {
        parts.push(fp.as_bytes().to_vec());
    }
    let refs: Vec<&[u8]> = parts.iter().map(|v| v.as_slice()).collect();
    content_hash_parts(&refs)
}

/// Execution-time key: static fingerprint + the compiled artifact's
/// fingerprint + the snapshot ids of the inputs the node reads, in the
/// node's declared input order (input order is semantic for binary ops).
pub fn run_cache_key(
    static_fp: &str,
    artifact_fp: &str,
    input_snapshots: &[String],
) -> CacheKey {
    let mut parts: Vec<&[u8]> = Vec::with_capacity(3 + input_snapshots.len());
    parts.push(KEY_DOMAIN.as_bytes());
    parts.push(static_fp.as_bytes());
    parts.push(artifact_fp.as_bytes());
    for s in input_snapshots {
        parts.push(s.as_bytes());
    }
    content_hash_parts(&parts)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::contracts::schema::{Field, Schema};
    use crate::contracts::types::{FieldType, LogicalType};

    #[test]
    fn contract_fingerprint_sees_bounds_and_annotations() {
        let base = Schema::new("S", vec![
            Field::new("x", FieldType::new(LogicalType::Float).bounded(0.0, 1.0)),
        ]);
        let wider = Schema::new("S", vec![
            Field::new("x", FieldType::new(LogicalType::Float).bounded(0.0, 2.0)),
        ]);
        let unique = Schema::new("S", vec![
            Field::new("x", FieldType::new(LogicalType::Float).bounded(0.0, 1.0)).unique(),
        ]);
        assert_ne!(contract_fingerprint(&base), contract_fingerprint(&wider));
        assert_ne!(contract_fingerprint(&base), contract_fingerprint(&unique));
        assert_eq!(contract_fingerprint(&base), contract_fingerprint(&base.clone()));
        // ... which Schema::fingerprint cannot distinguish
        assert_eq!(base.fingerprint(), wider.fingerprint());
    }

    #[test]
    fn static_fingerprint_is_param_bit_exact() {
        let a = node_static_fingerprint("child", &[0.5, 1.0], "out", &["in".into()]);
        let b = node_static_fingerprint("child", &[0.5, 1.0], "out", &["in".into()]);
        let c = node_static_fingerprint("child", &[0.5, 1.5], "out", &["in".into()]);
        assert_eq!(a, b);
        assert_ne!(a, c);
        assert_ne!(
            node_static_fingerprint("child", &[0.0], "out", &[]),
            node_static_fingerprint("child", &[-0.0], "out", &[]),
        );
    }

    #[test]
    fn run_key_covers_every_component_and_input_order() {
        let k = |sfp: &str, afp: &str, snaps: &[&str]| {
            run_cache_key(sfp, afp, &snaps.iter().map(|s| s.to_string()).collect::<Vec<_>>())
        };
        let base = k("sfp", "afp", &["snapA", "snapB"]);
        assert_eq!(base, k("sfp", "afp", &["snapA", "snapB"]));
        assert_ne!(base, k("sfp2", "afp", &["snapA", "snapB"]));
        assert_ne!(base, k("sfp", "afp2", &["snapA", "snapB"]));
        assert_ne!(base, k("sfp", "afp", &["snapB", "snapA"]));
        assert_ne!(base, k("sfp", "afp", &["snapA"]));
    }
}
