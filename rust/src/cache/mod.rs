//! Content-addressed run cache: memoized node executions for
//! incremental, replayable pipelines.
//!
//! The paper's programming model makes a node's output a pure function
//! of (code artifact, parameters, input snapshots, output contract) —
//! which is exactly a cache key ([`key`]). This module memoizes the
//! mapping `key -> published snapshot`, so a warm transactional re-run
//! publishes unchanged nodes by *committing the existing snapshot* to
//! the transactional branch instead of re-running the kernel; only the
//! edited node's downstream cone executes.
//!
//! Invariants (spec: `doc/RUN_CACHE.md`, enforced by
//! `tests/integration_cache.rs`):
//!
//! - **verify-before-populate** — an entry is inserted only after the
//!   run's step-3 verifiers passed on the transactional branch, so a
//!   cache hit never skips a check a fresh run would have enforced;
//! - **pin-while-cached** — every cached snapshot is pinned in the
//!   catalog ([`Catalog::pin_snapshot`](crate::catalog::Catalog::pin_snapshot)),
//!   so GC and branch deletion cannot invalidate an entry out from
//!   under it; eviction and `clear` release the pins;
//! - **LRU within a byte budget** — entries are evicted
//!   least-recently-hit first once the summed snapshot bytes exceed the
//!   budget;
//! - **advisory durability** — the index file ([`index`]) follows the
//!   journal's crc'd canonical-JSON conventions; a torn tail (or a
//!   missing file) costs recomputation, never correctness.
#![warn(missing_docs)]

pub mod index;
pub mod key;

use std::collections::HashMap;
use std::path::Path;
use std::sync::Mutex;

use crate::error::Result;
pub use index::{IndexLog, IndexOp, IndexRecord};
pub use key::{contract_fingerprint, node_static_fingerprint, run_cache_key, CacheKey};

/// File name of the cache index inside a durable lake directory.
pub const CACHE_INDEX_FILE: &str = "cache.jsonl";

/// One memoized node execution.
#[derive(Debug, Clone, PartialEq)]
pub struct CacheEntry {
    /// The run-cache key (see [`key::run_cache_key`]).
    pub key: CacheKey,
    /// The verified snapshot a hit republishes.
    pub snapshot_id: String,
    /// Physical bytes of the snapshot's data objects (budget +
    /// bytes-saved accounting).
    pub bytes: u64,
    /// Logical LRU clock of the last hit (or the insert).
    pub last_hit: u64,
}

/// Aggregate counters, exposed via [`RunCache::stats`] and mirrored
/// into the runner's `cache.*` metrics.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct CacheStats {
    /// Live entries.
    pub entries: usize,
    /// Summed bytes of live entries.
    pub total_bytes: u64,
    /// Lookups served from the cache.
    pub hits: u64,
    /// Lookups that fell through to execution (including stale entries).
    pub misses: u64,
    /// Entries inserted (post-verify).
    pub populated: u64,
    /// Entries evicted by the LRU byte budget.
    pub evictions: u64,
    /// Compute bytes not re-produced thanks to hits.
    pub bytes_saved: u64,
    /// Index-log append failures (the cache degrades to in-memory).
    pub log_errors: u64,
}

#[derive(Default)]
struct Inner {
    entries: HashMap<CacheKey, CacheEntry>,
    /// Logical LRU clock; persisted through `at` fields so recency
    /// survives a reopen.
    clock: u64,
    total_bytes: u64,
    log: Option<IndexLog>,
    hits: u64,
    misses: u64,
    populated: u64,
    evictions: u64,
    bytes_saved: u64,
    log_errors: u64,
}

impl Inner {
    /// Append to the index log, degrading to in-memory on I/O failure —
    /// the cache is an optimization and must never fail a run.
    fn log_op(&mut self, op: IndexOp) {
        let failed = match self.log.as_mut() {
            Some(log) => log.append(op).is_err(),
            None => false,
        };
        if failed {
            self.log = None;
            self.log_errors += 1;
        }
    }

    fn insert(&mut self, entry: CacheEntry) -> Option<CacheEntry> {
        self.total_bytes += entry.bytes;
        let prev = self.entries.insert(entry.key.clone(), entry);
        if let Some(p) = &prev {
            self.total_bytes -= p.bytes;
        }
        prev
    }

    fn remove(&mut self, key: &str) -> Option<CacheEntry> {
        let prev = self.entries.remove(key);
        if let Some(p) = &prev {
            self.total_bytes -= p.bytes;
        }
        prev
    }

    /// Evict least-recently-hit entries until `total_bytes <= budget`.
    fn evict_to(&mut self, budget: u64, log: bool) -> Vec<CacheEntry> {
        let mut evicted = Vec::new();
        while self.total_bytes > budget && !self.entries.is_empty() {
            // ties broken by key so eviction order is deterministic
            let victim = self
                .entries
                .values()
                .min_by(|a, b| a.last_hit.cmp(&b.last_hit).then(a.key.cmp(&b.key)))
                .map(|e| e.key.clone())
                .expect("non-empty");
            let e = self.remove(&victim).expect("present");
            if log {
                self.log_op(IndexOp::Remove { key: e.key.clone() });
            }
            self.evictions += 1;
            evicted.push(e);
        }
        evicted
    }
}

/// The run cache. Thread-safe; share via `Arc`.
pub struct RunCache {
    inner: Mutex<Inner>,
    byte_budget: u64,
}

impl RunCache {
    /// An in-memory cache with the given byte budget (no index file).
    pub fn in_memory(byte_budget: u64) -> RunCache {
        RunCache { inner: Mutex::new(Inner::default()), byte_budget }
    }

    /// Open (or create) a durable cache backed by the index log at
    /// `path`. Replays the valid prefix, repairs a torn tail, enforces
    /// the budget, and compacts the log when replay shows dead records.
    ///
    /// The caller is responsible for re-pinning the loaded entries
    /// against its catalog (see
    /// [`Client::attach_run_cache`](crate::client::Client::attach_run_cache))
    /// — an entry whose snapshot no longer resolves must be removed.
    pub fn open(path: impl AsRef<Path>, byte_budget: u64) -> Result<RunCache> {
        let (log, records) = IndexLog::open(path.as_ref())?;
        let mut inner = Inner { log: Some(log), ..Inner::default() };
        let replayed = records.len();
        Self::replay(&mut inner, records);
        // a shrunk budget applies immediately (dropped entries were
        // never re-pinned, so there is nothing to release)
        inner.evict_to(byte_budget, false);
        if replayed != inner.entries.len() {
            Self::compact_inner(&mut inner);
        }
        Ok(RunCache { inner: Mutex::new(inner), byte_budget })
    }

    /// A read-only view of the durable index at `path`: replays the
    /// valid prefix without creating, repairing, compacting, or holding
    /// a writable handle on the file — safe while another process has
    /// the cache open for writing (`cache stats`, GC root discovery).
    /// The returned cache has no log attached, so any mutation stays
    /// in-memory.
    pub fn open_read_only(path: impl AsRef<Path>, byte_budget: u64) -> Result<RunCache> {
        let records = IndexLog::scan(path.as_ref())?;
        let mut inner = Inner::default();
        Self::replay(&mut inner, records);
        inner.evict_to(byte_budget, false);
        Ok(RunCache { inner: Mutex::new(inner), byte_budget })
    }

    fn replay(inner: &mut Inner, records: Vec<IndexRecord>) {
        for rec in records {
            match rec.op {
                IndexOp::Put { key, snapshot_id, bytes, at } => {
                    inner.insert(CacheEntry { key, snapshot_id, bytes, last_hit: at });
                    inner.clock = inner.clock.max(at);
                }
                IndexOp::Hit { key, at } => {
                    if let Some(e) = inner.entries.get_mut(&key) {
                        e.last_hit = at;
                    }
                    inner.clock = inner.clock.max(at);
                }
                IndexOp::Remove { key } => {
                    inner.remove(&key);
                }
                IndexOp::Clear => {
                    inner.entries.clear();
                    inner.total_bytes = 0;
                }
            }
        }
    }

    fn compact_inner(inner: &mut Inner) {
        let mut ops: Vec<IndexOp> = inner
            .entries
            .values()
            .map(|e| IndexOp::Put {
                key: e.key.clone(),
                snapshot_id: e.snapshot_id.clone(),
                bytes: e.bytes,
                at: e.last_hit,
            })
            .collect();
        ops.sort_by(|a, b| match (a, b) {
            (IndexOp::Put { key: ka, .. }, IndexOp::Put { key: kb, .. }) => ka.cmp(kb),
            _ => std::cmp::Ordering::Equal,
        });
        let failed = match inner.log.as_mut() {
            Some(log) => log.rewrite(&ops).is_err(),
            None => false,
        };
        if failed {
            inner.log = None;
            inner.log_errors += 1;
        }
    }

    /// The configured byte budget.
    pub fn byte_budget(&self) -> u64 {
        self.byte_budget
    }

    /// Look up `key` without touching accounting (the runner validates
    /// the snapshot still resolves before declaring a hit).
    pub fn lookup(&self, key: &str) -> Option<CacheEntry> {
        self.inner.lock().unwrap().entries.get(key).cloned()
    }

    /// [`RunCache::lookup`] under a `cache.lookup` child of `span`. The
    /// span records the key and whether the index held an entry — the
    /// runner may still demote an index hit to a miss when the entry's
    /// snapshot no longer resolves, which the node span's `cache_hit`
    /// attribute captures.
    pub fn lookup_traced(&self, key: &str, span: &crate::trace::Span) -> Option<CacheEntry> {
        let ls = span.child("cache.lookup");
        ls.attr_str("key", key);
        let entry = self.lookup(key);
        ls.attr_bool("index_hit", entry.is_some());
        entry
    }

    /// Record a served hit: bumps the entry's LRU position and the
    /// hit/bytes-saved counters. Returns the bytes saved (0 if the
    /// entry vanished concurrently).
    pub fn mark_hit(&self, key: &str) -> u64 {
        let mut inner = self.inner.lock().unwrap();
        inner.clock += 1;
        let at = inner.clock;
        let bytes = match inner.entries.get_mut(key) {
            Some(e) => {
                e.last_hit = at;
                e.bytes
            }
            None => return 0,
        };
        inner.hits += 1;
        inner.bytes_saved += bytes;
        inner.log_op(IndexOp::Hit { key: key.to_string(), at });
        bytes
    }

    /// Record a lookup that fell through to execution.
    pub fn mark_miss(&self) {
        self.inner.lock().unwrap().misses += 1;
    }

    /// Drop an entry (stale snapshot, external invalidation). Returns
    /// the removed entry so the caller can release its pin.
    pub fn remove(&self, key: &str) -> Option<CacheEntry> {
        let mut inner = self.inner.lock().unwrap();
        let prev = inner.remove(key);
        if prev.is_some() {
            inner.log_op(IndexOp::Remove { key: key.to_string() });
        }
        prev
    }

    /// Insert a verified `key -> snapshot` mapping and enforce the byte
    /// budget. Returns whether the mapping was actually inserted (false
    /// when an identical entry already exists — the caller must then
    /// release the pin it acquired) plus every entry this displaced —
    /// the replaced previous mapping (if any) and LRU evictions — so
    /// the caller can release their pins too.
    pub fn populate(&self, key: &str, snapshot_id: &str, bytes: u64) -> (bool, Vec<CacheEntry>) {
        let mut inner = self.inner.lock().unwrap();
        if let Some(existing) = inner.entries.get(key) {
            if existing.snapshot_id == snapshot_id {
                return (false, Vec::new()); // already cached; keep its LRU position
            }
        }
        inner.clock += 1;
        let at = inner.clock;
        let entry = CacheEntry {
            key: key.to_string(),
            snapshot_id: snapshot_id.to_string(),
            bytes,
            last_hit: at,
        };
        inner.log_op(IndexOp::Put {
            key: entry.key.clone(),
            snapshot_id: entry.snapshot_id.clone(),
            bytes,
            at,
        });
        let mut displaced = Vec::new();
        if let Some(prev) = inner.insert(entry) {
            displaced.push(prev);
        }
        inner.populated += 1;
        displaced.extend(inner.evict_to(self.byte_budget, true));
        (true, displaced)
    }

    /// Drop every entry. Returns them so the caller can release pins.
    pub fn clear(&self) -> Vec<CacheEntry> {
        let mut inner = self.inner.lock().unwrap();
        let out: Vec<CacheEntry> = inner.entries.drain().map(|(_, e)| e).collect();
        inner.total_bytes = 0;
        if !out.is_empty() {
            inner.log_op(IndexOp::Clear);
        }
        out
    }

    /// Every live entry, sorted by key (stable output for CLI/tests).
    pub fn entries(&self) -> Vec<CacheEntry> {
        let inner = self.inner.lock().unwrap();
        let mut v: Vec<CacheEntry> = inner.entries.values().cloned().collect();
        v.sort_by(|a, b| a.key.cmp(&b.key));
        v
    }

    /// Live entry count.
    pub fn len(&self) -> usize {
        self.inner.lock().unwrap().entries.len()
    }

    /// Is the cache empty?
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Aggregate counters.
    pub fn stats(&self) -> CacheStats {
        let inner = self.inner.lock().unwrap();
        CacheStats {
            entries: inner.entries.len(),
            total_bytes: inner.total_bytes,
            hits: inner.hits,
            misses: inner.misses,
            populated: inner.populated,
            evictions: inner.evictions,
            bytes_saved: inner.bytes_saved,
            log_errors: inner.log_errors,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn populate_lookup_hit_cycle() {
        let c = RunCache::in_memory(u64::MAX);
        assert!(c.lookup("k1").is_none());
        c.mark_miss();
        let (inserted, displaced) = c.populate("k1", "snap1", 100);
        assert!(inserted && displaced.is_empty());
        let e = c.lookup("k1").unwrap();
        assert_eq!(e.snapshot_id, "snap1");
        assert_eq!(c.mark_hit("k1"), 100);
        let s = c.stats();
        assert_eq!((s.hits, s.misses, s.populated), (1, 1, 1));
        assert_eq!(s.bytes_saved, 100);
        assert_eq!(s.total_bytes, 100);
    }

    #[test]
    fn replacing_a_key_returns_the_old_entry() {
        let c = RunCache::in_memory(u64::MAX);
        c.populate("k", "snapA", 10);
        // same snapshot: no-op, and the caller learns it must unpin
        let (inserted, displaced) = c.populate("k", "snapA", 10);
        assert!(!inserted && displaced.is_empty());
        // new snapshot: old entry handed back for unpinning
        let (inserted, displaced) = c.populate("k", "snapB", 20);
        assert!(inserted);
        assert_eq!(displaced.len(), 1);
        assert_eq!(displaced[0].snapshot_id, "snapA");
        assert_eq!(c.stats().total_bytes, 20);
        assert_eq!(c.len(), 1);
    }

    #[test]
    fn lru_eviction_respects_byte_budget_and_recency() {
        let c = RunCache::in_memory(250);
        c.populate("a", "sa", 100);
        c.populate("b", "sb", 100);
        c.mark_hit("a"); // b is now least-recently-hit
        let (_, evicted) = c.populate("c", "sc", 100);
        assert_eq!(evicted.len(), 1);
        assert_eq!(evicted[0].key, "b");
        assert!(c.lookup("a").is_some());
        assert!(c.lookup("c").is_some());
        assert_eq!(c.stats().evictions, 1);
        assert!(c.stats().total_bytes <= 250);
    }

    #[test]
    fn clear_returns_everything() {
        let c = RunCache::in_memory(u64::MAX);
        c.populate("a", "sa", 1);
        c.populate("b", "sb", 2);
        let cleared = c.clear();
        assert_eq!(cleared.len(), 2);
        assert!(c.is_empty());
        assert_eq!(c.stats().total_bytes, 0);
    }

    #[test]
    fn durable_cache_survives_reopen_with_lru_order() {
        let dir = std::env::temp_dir().join(format!("bpl_rc_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("cache.jsonl");
        {
            let c = RunCache::open(&path, u64::MAX).unwrap();
            c.populate("a", "sa", 100);
            c.populate("b", "sb", 100);
            c.mark_hit("a");
        }
        {
            let c = RunCache::open(&path, u64::MAX).unwrap();
            assert_eq!(c.len(), 2);
            // recency survived the reopen: with a tight budget, b evicts
            let (_, evicted) = c.populate("c", "sc", 1);
            assert!(evicted.is_empty());
        }
        {
            let c = RunCache::open(&path, 200).unwrap();
            // budget shrink applies at open: b (LRU) dropped
            assert_eq!(c.len(), 2);
            assert!(c.lookup("b").is_none());
            assert!(c.lookup("a").is_some());
            assert!(c.lookup("c").is_some());
        }
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn read_only_open_never_touches_the_file() {
        let dir = std::env::temp_dir().join(format!("bpl_rcro_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("cache.jsonl");
        {
            let c = RunCache::open(&path, u64::MAX).unwrap();
            c.populate("a", "sa", 10);
            c.populate("b", "sb", 20);
            c.mark_hit("a"); // a hit record => a writable open would compact
        }
        // add a torn tail: a writable open would truncate it away
        {
            use std::io::Write;
            let mut f = std::fs::OpenOptions::new().append(true).open(&path).unwrap();
            f.write_all(b"{\"crc\":\"torn").unwrap();
        }
        let before = std::fs::read(&path).unwrap();
        let ro = RunCache::open_read_only(&path, u64::MAX).unwrap();
        assert_eq!(ro.len(), 2);
        assert_eq!(ro.stats().total_bytes, 30);
        // mutations on a read-only view stay in-memory
        ro.clear();
        assert_eq!(std::fs::read(&path).unwrap(), before, "read-only open wrote to the index");
        // and a missing file is just an empty view, not a created file
        let ghost = dir.join("nope.jsonl");
        assert!(RunCache::open_read_only(&ghost, u64::MAX).unwrap().is_empty());
        assert!(!ghost.exists());
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn corrupt_index_is_safely_discarded() {
        let dir = std::env::temp_dir().join(format!("bpl_rcbad_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("cache.jsonl");
        std::fs::write(&path, "this is not a cache index\n").unwrap();
        let c = RunCache::open(&path, u64::MAX).unwrap();
        assert!(c.is_empty());
        // and it is usable again
        c.populate("k", "s", 1);
        let c2 = RunCache::open(&path, u64::MAX).unwrap();
        assert_eq!(c2.len(), 1);
        let _ = std::fs::remove_dir_all(&dir);
    }
}
