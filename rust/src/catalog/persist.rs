//! Catalog persistence: the canonical-JSON codecs, whole-state
//! export/import, and the checkpoint files of the durable commit
//! pipeline.
//!
//! Together with a disk-backed [`ObjectStore`](crate::storage::ObjectStore)
//! this makes a lake durable. Two persistence layers share the codecs in
//! this module:
//!
//! - **The snapshot chain** (`snapshots/base-*.json` +
//!   `snapshots/delta-*-*.json`): the LSM-style checkpoint store.
//!   [`Catalog::checkpoint`] flushes only the entries touched since the
//!   last flush as an immutable *delta* segment (memtable → SST);
//!   compaction folds base + deltas into a fresh *base* snapshot (the
//!   full canonical export) and retires covered journal segments. The
//!   export is canonical (sorted keys, stable number formatting), so its
//!   content hash doubles as a lake-state fingerprint — two exports are
//!   byte-identical iff the catalogs are.
//! - **The journal** ([`journal`](crate::catalog::journal)): per-mutation
//!   records appended between checkpoints; recovery replays the segments
//!   the snapshot chain does not cover.
//!
//! The legacy single-file flow (`save(dir)` / `Catalog::load(dir)`) still
//! works for read-only reopening, but a journaled lake should be opened
//! with [`Catalog::recover`] so the journal tail is honoured — `load`
//! reads the checkpoint alone. Recovery also still understands the
//! pre-segmented layout (`catalog.json` + `checkpoint.json`) and migrates
//! it forward on the first open.

use std::collections::BTreeMap;
use std::path::Path;
use std::sync::Arc;

use crate::catalog::commit::Commit;
use crate::catalog::refs::{BranchInfo, BranchState};
use crate::catalog::service::StateDump;
use crate::catalog::Catalog;
use crate::catalog::snapshot::Snapshot;
use crate::error::{BauplanError, Result};
use crate::storage::ObjectStore;
use crate::util::json::Json;

/// Sidecar file recording which journal records the checkpoint covers.
pub(crate) const CHECKPOINT_META_FILE: &str = "checkpoint.json";

pub(crate) fn branch_state_str(s: BranchState) -> &'static str {
    match s {
        BranchState::Open => "open",
        BranchState::Merged => "merged",
        BranchState::Aborted => "aborted",
    }
}

pub(crate) fn parse_branch_state(s: &str) -> Result<BranchState> {
    match s {
        "open" => Ok(BranchState::Open),
        "merged" => Ok(BranchState::Merged),
        "aborted" => Ok(BranchState::Aborted),
        other => Err(BauplanError::Parse(format!("bad branch state '{other}'"))),
    }
}

/// Canonical JSON body of a commit (the id is carried by the caller —
/// as the map key in exports, as `commit_id` in journal records).
pub(crate) fn commit_to_json(c: &Commit) -> Json {
    Json::obj(vec![
        ("parents", Json::Arr(c.parents.iter().map(Json::str).collect())),
        (
            "tables",
            Json::Obj(c.tables.iter().map(|(t, s)| (t.clone(), Json::str(s))).collect()),
        ),
        ("author", Json::str(&c.author)),
        ("message", Json::str(&c.message)),
        ("run_id", c.run_id.as_ref().map(Json::str).unwrap_or(Json::Null)),
        ("timestamp_micros", Json::num(c.timestamp_micros as f64)),
    ])
}

/// Inverse of [`commit_to_json`]; lenient on missing fields (defaults),
/// matching the import behaviour the seed shipped with.
pub(crate) fn commit_from_json(id: &str, c: &Json) -> Commit {
    let parents = c
        .get("parents")
        .as_arr()
        .unwrap_or(&[])
        .iter()
        .filter_map(|p| p.as_str().map(String::from))
        .collect::<Vec<_>>();
    let tables = c
        .get("tables")
        .as_obj()
        .map(|o| {
            o.iter()
                .filter_map(|(t, s)| s.as_str().map(|s| (t.clone(), s.to_string())))
                .collect::<BTreeMap<_, _>>()
        })
        .unwrap_or_default();
    Commit {
        id: id.to_string(),
        parents,
        tables,
        author: c.get("author").as_str().unwrap_or("").to_string(),
        message: c.get("message").as_str().unwrap_or("").to_string(),
        run_id: c.get("run_id").as_str().map(String::from),
        timestamp_micros: c.get("timestamp_micros").as_f64().unwrap_or(0.0) as u64,
    }
}

/// Canonical JSON body of a snapshot (id carried by the caller).
pub(crate) fn snapshot_to_json(s: &Snapshot) -> Json {
    Json::obj(vec![
        ("objects", Json::Arr(s.objects.iter().map(Json::str).collect())),
        ("schema_name", Json::str(&s.schema_name)),
        ("schema_fingerprint", Json::str(&s.schema_fingerprint)),
        ("row_count", Json::num(s.row_count as f64)),
        ("run_id", Json::str(&s.run_id)),
    ])
}

/// Inverse of [`snapshot_to_json`].
pub(crate) fn snapshot_from_json(id: &str, s: &Json) -> Snapshot {
    Snapshot {
        id: id.to_string(),
        objects: s
            .get("objects")
            .as_arr()
            .unwrap_or(&[])
            .iter()
            .filter_map(|o| o.as_str().map(String::from))
            .collect(),
        schema_name: s.get("schema_name").as_str().unwrap_or("").to_string(),
        schema_fingerprint: s.get("schema_fingerprint").as_str().unwrap_or("").to_string(),
        row_count: s.get("row_count").as_f64().unwrap_or(0.0) as u64,
        run_id: s.get("run_id").as_str().unwrap_or("").to_string(),
    }
}

/// Canonical JSON body of a branch (name carried by the caller).
pub(crate) fn branch_to_json(b: &BranchInfo) -> Json {
    Json::obj(vec![
        ("head", Json::str(&b.head)),
        ("state", Json::str(branch_state_str(b.state))),
        ("transactional", Json::Bool(b.transactional)),
        ("owner_run", b.owner_run.as_ref().map(Json::str).unwrap_or(Json::Null)),
    ])
}

/// Inverse of [`branch_to_json`].
pub(crate) fn branch_from_json(name: &str, b: &Json) -> Result<BranchInfo> {
    Ok(BranchInfo {
        name: name.to_string(),
        head: b.get("head").as_str().unwrap_or("").to_string(),
        state: parse_branch_state(b.get("state").as_str().unwrap_or("open"))?,
        transactional: b.get("transactional").as_bool().unwrap_or(false),
        owner_run: b.get("owner_run").as_str().map(String::from),
    })
}

/// Build the canonical export document from a consistent state dump.
pub(crate) fn export_json(dump: &StateDump) -> Json {
    let mut commits = BTreeMap::new();
    let mut snapshots = BTreeMap::new();
    let mut branches = BTreeMap::new();
    let mut tags = BTreeMap::new();
    for (id, c) in &dump.commits {
        commits.insert(id.clone(), commit_to_json(c));
    }
    for (id, s) in &dump.snapshots {
        snapshots.insert(id.clone(), snapshot_to_json(s));
    }
    for b in &dump.branches {
        branches.insert(b.name.clone(), branch_to_json(b));
    }
    for (name, target) in &dump.tags {
        tags.insert(name.clone(), Json::str(target));
    }
    let mut runs = BTreeMap::new();
    for (id, record) in &dump.runs {
        runs.insert(id.clone(), record.clone());
    }
    let mut traces = BTreeMap::new();
    for (id, trace) in &dump.traces {
        traces.insert(id.clone(), trace.clone());
    }
    Json::obj(vec![
        ("version", Json::num(1.0)),
        ("commits", Json::Obj(commits)),
        ("snapshots", Json::Obj(snapshots)),
        ("branches", Json::Obj(branches)),
        ("tags", Json::Obj(tags)),
        ("runs", Json::Obj(runs)),
        ("traces", Json::Obj(traces)),
    ])
}

/// Write `bytes` to `dir/name` atomically: temp file → fsync → rename.
fn write_atomic(dir: &Path, name: &str, bytes: &[u8]) -> Result<()> {
    let tmp = dir.join(format!("{name}.tmp"));
    {
        let mut f = std::fs::File::create(&tmp)?;
        use std::io::Write;
        f.write_all(bytes)?;
        f.sync_data()?;
    }
    std::fs::rename(&tmp, dir.join(name))?;
    // make the rename itself durable
    if let Ok(d) = std::fs::File::open(dir) {
        let _ = d.sync_all();
    }
    Ok(())
}

/// Write the checkpoint pair: the canonical export, then the metadata
/// naming the last journal sequence number the export covers.
///
/// Crash-ordering argument (spec §Checkpoint): if the process dies after
/// `catalog.json` lands but before `checkpoint.json` (or before the
/// journal truncation), recovery replays journal records that are already
/// reflected in the export — replay is ordered and idempotent, so the
/// recovered state is identical.
pub(crate) fn write_checkpoint(dir: &Path, export: &Json, journal_seq: u64) -> Result<()> {
    std::fs::create_dir_all(dir)?;
    write_atomic(dir, "catalog.json", export.to_string().as_bytes())?;
    let meta = Json::obj(vec![
        ("journal_seq", Json::num(journal_seq as f64)),
        ("version", Json::num(1.0)),
    ]);
    write_atomic(dir, CHECKPOINT_META_FILE, meta.to_string().as_bytes())?;
    Ok(())
}

/// The journal floor of the checkpoint in `dir` (0 when no checkpoint
/// metadata exists — every journal record replays).
pub(crate) fn read_checkpoint_seq(dir: &Path) -> Result<u64> {
    let path = dir.join(CHECKPOINT_META_FILE);
    if !path.exists() {
        return Ok(0);
    }
    let text = std::fs::read_to_string(path)?;
    let v = Json::parse(&text)?;
    Ok(v.get("journal_seq").as_f64().unwrap_or(0.0) as u64)
}

// ------------------------------------------------------- snapshot chain

/// Directory (under the lake dir) holding the snapshot chain: immutable
/// `base-*.json` full exports and `delta-*-*.json` incremental
/// checkpoints.
pub(crate) const SNAPSHOT_DIR: &str = "snapshots";

pub(crate) fn base_name(seq: u64) -> String {
    format!("base-{seq:020}.json")
}

pub(crate) fn delta_name(from_seq: u64, to_seq: u64) -> String {
    format!("delta-{from_seq:020}-{to_seq:020}.json")
}

pub(crate) fn parse_base_name(name: &str) -> Option<u64> {
    let digits = name.strip_prefix("base-")?.strip_suffix(".json")?;
    digits.parse().ok()
}

pub(crate) fn parse_delta_name(name: &str) -> Option<(u64, u64)> {
    let body = name.strip_prefix("delta-")?.strip_suffix(".json")?;
    let (from, to) = body.split_once('-')?;
    Some((from.parse().ok()?, to.parse().ok()?))
}

/// One incremental checkpoint: the entries upserted (and branches
/// deleted) over journal sequence range `(from_seq, to_seq]`.
pub(crate) struct SnapshotDelta {
    /// The journal floor the delta chains onto (exclusive).
    pub from_seq: u64,
    /// The journal sequence the delta covers through (inclusive).
    pub to_seq: u64,
    /// The delta document: `{version, from_seq, to_seq, upserts, branches_deleted}`.
    pub json: Json,
}

/// The recovery view of the snapshot chain: the newest base export (if
/// any) plus the contiguous run of deltas chaining from it.
pub(crate) struct SnapshotChain {
    /// Journal sequence the base covers (0 when starting from the
    /// implicit empty-lake state).
    pub base_seq: u64,
    /// The base full export, or `None` when only deltas exist (a fresh
    /// lake checkpointed before its first compaction).
    pub base_state: Option<Json>,
    /// Deltas in chain order; `deltas[0].from_seq == base_seq` and each
    /// subsequent `from_seq` equals the previous `to_seq`.
    pub deltas: Vec<SnapshotDelta>,
}

impl SnapshotChain {
    /// The journal sequence the whole chain covers.
    pub fn covered_seq(&self) -> u64 {
        self.deltas.last().map(|d| d.to_seq).unwrap_or(self.base_seq)
    }
}

/// Read the snapshot chain under `dir`: pick the newest base, then chain
/// every delta whose `from_seq` continues the cover. Stale files (older
/// bases, deltas at or below the cover) are ignored — compaction retires
/// them lazily — but a gap in the chain stops it: later deltas cannot
/// apply without their predecessor. Returns `Ok(None)` when no snapshot
/// chain exists (fresh or legacy-layout lake).
pub(crate) fn read_snapshot_chain(dir: &Path) -> Result<Option<SnapshotChain>> {
    let snap_dir = dir.join(SNAPSHOT_DIR);
    if !snap_dir.is_dir() {
        return Ok(None);
    }
    let mut bases: Vec<u64> = Vec::new();
    let mut deltas: Vec<(u64, u64)> = Vec::new();
    for entry in std::fs::read_dir(&snap_dir)? {
        let name = entry?.file_name();
        let name = name.to_string_lossy();
        if let Some(seq) = parse_base_name(&name) {
            bases.push(seq);
        } else if let Some((from, to)) = parse_delta_name(&name) {
            deltas.push((from, to));
        }
        // anything else (.tmp leftovers, strays) is not part of the chain
    }
    if bases.is_empty() && deltas.is_empty() {
        return Ok(None);
    }

    let (base_seq, base_state) = match bases.iter().max() {
        Some(&seq) => {
            let path = snap_dir.join(base_name(seq));
            let text = std::fs::read_to_string(&path)?;
            let doc = Json::parse(&text).map_err(|e| {
                BauplanError::Parse(format!("snapshot base {}: {e}", path.display()))
            })?;
            let state = doc.get("state").clone();
            if state.as_obj().is_none() {
                return Err(BauplanError::Parse(format!(
                    "snapshot base {}: missing state",
                    path.display()
                )));
            }
            (seq, Some(state))
        }
        None => (0, None),
    };

    deltas.sort_unstable();
    let mut chain = Vec::new();
    let mut cover = base_seq;
    for (from, to) in deltas {
        if to <= cover {
            continue; // folded into the base (or an earlier delta) already
        }
        if from != cover {
            break; // gap: the rest of the chain cannot apply
        }
        let path = snap_dir.join(delta_name(from, to));
        let text = std::fs::read_to_string(&path)?;
        let json = Json::parse(&text).map_err(|e| {
            BauplanError::Parse(format!("snapshot delta {}: {e}", path.display()))
        })?;
        chain.push(SnapshotDelta { from_seq: from, to_seq: to, json });
        cover = to;
    }
    Ok(Some(SnapshotChain { base_seq, base_state, deltas: chain }))
}

/// Write an immutable base snapshot covering journal sequence `seq`:
/// the full canonical export, atomically, into the snapshot dir.
pub(crate) fn write_base(dir: &Path, export: &Json, seq: u64) -> Result<()> {
    let snap_dir = dir.join(SNAPSHOT_DIR);
    std::fs::create_dir_all(&snap_dir)?;
    let doc = Json::obj(vec![
        ("journal_seq", Json::num(seq as f64)),
        ("state", export.clone()),
        ("version", Json::num(1.0)),
    ]);
    write_atomic(&snap_dir, &base_name(seq), doc.to_string().as_bytes())
}

/// Write an immutable delta snapshot covering `(from_seq, to_seq]`.
pub(crate) fn write_delta(dir: &Path, delta: &Json, from_seq: u64, to_seq: u64) -> Result<()> {
    let snap_dir = dir.join(SNAPSHOT_DIR);
    std::fs::create_dir_all(&snap_dir)?;
    write_atomic(&snap_dir, &delta_name(from_seq, to_seq), delta.to_string().as_bytes())
}

/// After a compaction wrote a base at `seq`, retire everything it
/// subsumes: older bases and deltas fully at or below `seq`. Best
/// effort — a file that refuses to die is ignored by the chain reader
/// anyway. Also clears legacy single-file checkpoints, which the base
/// supersedes.
pub(crate) fn remove_stale_snapshots(dir: &Path, seq: u64) {
    let snap_dir = dir.join(SNAPSHOT_DIR);
    if let Ok(entries) = std::fs::read_dir(&snap_dir) {
        for entry in entries.flatten() {
            let name = entry.file_name();
            let name = name.to_string_lossy().into_owned();
            let stale = match (parse_base_name(&name), parse_delta_name(&name)) {
                (Some(b), _) => b < seq,
                (_, Some((_, to))) => to <= seq,
                _ => name.ends_with(".tmp"),
            };
            if stale {
                let _ = std::fs::remove_file(entry.path());
            }
        }
    }
    let _ = std::fs::remove_file(dir.join("catalog.json"));
    let _ = std::fs::remove_file(dir.join(CHECKPOINT_META_FILE));
}

impl Catalog {
    /// Serialize the full catalog state to canonical JSON (one consistent
    /// view: taken under a single read lock).
    pub fn export(&self) -> Json {
        export_json(&self.dump_state())
    }

    /// Write `catalog.json` under `dir`.
    ///
    /// Legacy whole-state flow — O(total history) per call. Journaled
    /// lakes should prefer [`Catalog::checkpoint`], which also records
    /// the covered journal floor and truncates the journal.
    pub fn save(&self, dir: &Path) -> Result<()> {
        std::fs::create_dir_all(dir)?;
        std::fs::write(dir.join("catalog.json"), self.export().to_string())?;
        Ok(())
    }

    /// Rebuild a catalog from an export, bound to `store`.
    pub fn import(json: &Json, store: Arc<ObjectStore>) -> Result<Catalog> {
        let cat = Catalog::new(store);

        let commits_j = json.get("commits").as_obj().ok_or_else(|| {
            BauplanError::Parse("catalog export: missing commits".into())
        })?;
        let mut commits = Vec::new();
        for (id, c) in commits_j {
            commits.push(commit_from_json(id, c));
        }

        let snapshots_j = json.get("snapshots").as_obj().ok_or_else(|| {
            BauplanError::Parse("catalog export: missing snapshots".into())
        })?;
        let mut snapshots = Vec::new();
        for (id, s) in snapshots_j {
            snapshots.push(snapshot_from_json(id, s));
        }

        let mut branches = Vec::new();
        if let Some(bs) = json.get("branches").as_obj() {
            for (name, b) in bs {
                branches.push(branch_from_json(name, b)?);
            }
        }
        let mut tags = Vec::new();
        if let Some(ts) = json.get("tags").as_obj() {
            for (name, t) in ts {
                tags.push((name.clone(), t.as_str().unwrap_or("").to_string()));
            }
        }

        cat.restore(commits, snapshots, branches, tags)?;
        // run records are opaque to the catalog; lenient on absence so
        // pre-scheduler exports (no "runs" key) import unchanged
        if let Some(rs) = json.get("runs").as_obj() {
            cat.set_run_records(rs.iter().map(|(k, r)| (k.clone(), r.clone())).collect());
        }
        // run traces arrived with the tracing layer; same leniency
        if let Some(ts) = json.get("traces").as_obj() {
            cat.set_run_traces(ts.iter().map(|(k, t)| (k.clone(), t.clone())).collect());
        }
        Ok(cat)
    }

    /// Reopen a lake persisted with [`Catalog::save`] + a disk store.
    ///
    /// Reads the checkpoint only — a journaled lake directory should be
    /// opened with [`Catalog::recover`] instead, which also replays the
    /// journal tail.
    pub fn load(dir: &Path) -> Result<Catalog> {
        let store = Arc::new(ObjectStore::on_disk(dir.join("objects"))?);
        let text = std::fs::read_to_string(dir.join("catalog.json"))?;
        Catalog::import(&Json::parse(&text)?, store)
    }

    /// Save a fully durable lake: catalog.json + all objects on disk.
    /// (If the store is already disk-backed this only writes the json.)
    pub fn save_full(&self, dir: &Path) -> Result<()> {
        std::fs::create_dir_all(dir.join("objects"))?;
        // ensure every reachable object is on disk
        for (_, snap) in self.dump_snapshots() {
            for key in &snap.objects {
                let path = dir.join("objects").join(key);
                if !path.exists() {
                    let data = self.store().get(key)?;
                    std::fs::write(&path, data)?;
                }
            }
        }
        self.save(dir)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::catalog::MAIN;
    use crate::testing::commit_table;

    fn populated() -> Catalog {
        let c = Catalog::new(Arc::new(ObjectStore::new()));
        let key = c.store().put(vec![1, 2, 3]);
        commit_table(
            &c,
            MAIN,
            "t",
            Snapshot::new(vec![key], "S", "fp", 3, "r1"),
            "u",
            "first",
            Some("r1".into()),
        )
        .unwrap();
        c.create_branch("dev", MAIN, false).unwrap();
        c.tag("v1", MAIN).unwrap();
        c.create_txn_branch(MAIN, "r2").unwrap();
        c.set_branch_state("txn/r2", BranchState::Aborted).unwrap();
        c.put_run_record(
            "run_1",
            Json::obj(vec![("pipeline", Json::str("paper_dag"))]),
        )
        .unwrap();
        c
    }

    #[test]
    fn export_import_roundtrip() {
        let c = populated();
        let json = c.export();
        let c2 = Catalog::import(&json, c.store().clone()).unwrap();
        assert_eq!(c.export().to_string(), c2.export().to_string());
        // refs behave identically
        assert_eq!(c.resolve(MAIN).unwrap(), c2.resolve(MAIN).unwrap());
        assert_eq!(c.resolve("v1").unwrap(), c2.resolve("v1").unwrap());
        // guardrail state survives
        let b = c2.branch_info("txn/r2").unwrap();
        assert_eq!(b.state, BranchState::Aborted);
        assert!(b.transactional);
        // run records survive the roundtrip
        assert_eq!(
            c2.get_run_record("run_1").unwrap().get("pipeline").as_str(),
            Some("paper_dag")
        );
    }

    #[test]
    fn import_without_runs_key_is_lenient() {
        // pre-scheduler exports carry no "runs" map
        let c = populated();
        let mut obj = c.export().as_obj().unwrap().clone();
        obj.remove("runs");
        let c2 = Catalog::import(&Json::Obj(obj), c.store().clone()).unwrap();
        assert!(c2.get_run_record("run_1").is_none());
        assert_eq!(c.resolve(MAIN).unwrap(), c2.resolve(MAIN).unwrap());
    }

    #[test]
    fn export_is_canonical() {
        let c = populated();
        assert_eq!(c.export().to_string(), c.export().to_string());
    }

    #[test]
    fn save_load_from_disk() {
        let dir = std::env::temp_dir().join(format!("bpl_persist_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let c = populated();
        c.save_full(&dir).unwrap();

        let c2 = Catalog::load(&dir).unwrap();
        assert_eq!(c2.resolve(MAIN).unwrap(), c.resolve(MAIN).unwrap());
        // data objects are readable through the disk store
        let head = c2.read_ref(MAIN).unwrap();
        let snap = c2.get_snapshot(&head.tables["t"]).unwrap();
        assert_eq!(&*c2.store().get(&snap.objects[0]).unwrap(), &[1u8, 2, 3][..]);
        // history intact
        assert_eq!(c2.log(MAIN, 10).unwrap().len(), 2);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn import_rejects_garbage() {
        let store = Arc::new(ObjectStore::new());
        assert!(Catalog::import(&Json::parse("{}").unwrap(), store.clone()).is_err());
        assert!(Catalog::import(&Json::parse(r#"{"commits": {}}"#).unwrap(), store).is_err());
    }

    #[test]
    fn checkpoint_meta_roundtrip() {
        let dir = std::env::temp_dir().join(format!("bpl_ckptmeta_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        assert_eq!(read_checkpoint_seq(&dir).unwrap(), 0);
        write_checkpoint(&dir, &populated().export(), 17).unwrap();
        assert_eq!(read_checkpoint_seq(&dir).unwrap(), 17);
        // no stray temp files survive the atomic writes
        let leftovers: Vec<_> = std::fs::read_dir(&dir)
            .unwrap()
            .filter_map(|e| e.ok())
            .filter(|e| e.file_name().to_string_lossy().ends_with(".tmp"))
            .collect();
        assert!(leftovers.is_empty());
        let _ = std::fs::remove_dir_all(&dir);
    }
}
