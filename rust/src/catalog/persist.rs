//! Catalog persistence: export/import the full ref + commit + snapshot
//! state as deterministic JSON.
//!
//! Together with a disk-backed [`ObjectStore`](crate::storage::ObjectStore)
//! this makes a lake durable: `save(dir)` writes `catalog.json` next to
//! the object files; `Catalog::load(dir)` reopens it. The export is
//! canonical (sorted keys, stable number formatting), so its content hash
//! doubles as a lake-state fingerprint — two exports are byte-identical
//! iff the catalogs are.

use std::collections::BTreeMap;
use std::path::Path;
use std::sync::Arc;

use crate::catalog::commit::Commit;
use crate::catalog::refs::{BranchInfo, BranchState};
use crate::catalog::Catalog;
use crate::catalog::snapshot::Snapshot;
use crate::error::{BauplanError, Result};
use crate::storage::ObjectStore;
use crate::util::json::Json;

fn branch_state_str(s: BranchState) -> &'static str {
    match s {
        BranchState::Open => "open",
        BranchState::Merged => "merged",
        BranchState::Aborted => "aborted",
    }
}

fn parse_branch_state(s: &str) -> Result<BranchState> {
    match s {
        "open" => Ok(BranchState::Open),
        "merged" => Ok(BranchState::Merged),
        "aborted" => Ok(BranchState::Aborted),
        other => Err(BauplanError::Parse(format!("bad branch state '{other}'"))),
    }
}

impl Catalog {
    /// Serialize the full catalog state to canonical JSON.
    pub fn export(&self) -> Json {
        let mut commits = BTreeMap::new();
        let mut snapshots = BTreeMap::new();
        let mut branches = BTreeMap::new();
        let mut tags = BTreeMap::new();

        for (id, c) in self.dump_commits() {
            commits.insert(
                id,
                Json::obj(vec![
                    ("parents", Json::Arr(c.parents.iter().map(Json::str).collect())),
                    ("tables", Json::Obj(
                        c.tables.iter().map(|(t, s)| (t.clone(), Json::str(s))).collect(),
                    )),
                    ("author", Json::str(&c.author)),
                    ("message", Json::str(&c.message)),
                    ("run_id", c.run_id.as_ref().map(Json::str).unwrap_or(Json::Null)),
                    ("timestamp_micros", Json::num(c.timestamp_micros as f64)),
                ]),
            );
        }
        for (id, s) in self.dump_snapshots() {
            snapshots.insert(
                id,
                Json::obj(vec![
                    ("objects", Json::Arr(s.objects.iter().map(Json::str).collect())),
                    ("schema_name", Json::str(&s.schema_name)),
                    ("schema_fingerprint", Json::str(&s.schema_fingerprint)),
                    ("row_count", Json::num(s.row_count as f64)),
                    ("run_id", Json::str(&s.run_id)),
                ]),
            );
        }
        for b in self.list_branches() {
            branches.insert(
                b.name.clone(),
                Json::obj(vec![
                    ("head", Json::str(&b.head)),
                    ("state", Json::str(branch_state_str(b.state))),
                    ("transactional", Json::Bool(b.transactional)),
                    ("owner_run", b.owner_run.as_ref().map(Json::str).unwrap_or(Json::Null)),
                ]),
            );
        }
        for (name, target) in self.dump_tags() {
            tags.insert(name, Json::str(&target));
        }

        Json::obj(vec![
            ("version", Json::num(1.0)),
            ("commits", Json::Obj(commits)),
            ("snapshots", Json::Obj(snapshots)),
            ("branches", Json::Obj(branches)),
            ("tags", Json::Obj(tags)),
        ])
    }

    /// Write `catalog.json` under `dir`.
    pub fn save(&self, dir: &Path) -> Result<()> {
        std::fs::create_dir_all(dir)?;
        std::fs::write(dir.join("catalog.json"), self.export().to_string())?;
        Ok(())
    }

    /// Rebuild a catalog from an export, bound to `store`.
    pub fn import(json: &Json, store: Arc<ObjectStore>) -> Result<Catalog> {
        let cat = Catalog::new(store);

        let commits_j = json.get("commits").as_obj().ok_or_else(|| {
            BauplanError::Parse("catalog export: missing commits".into())
        })?;
        let mut commits = Vec::new();
        for (id, c) in commits_j {
            let parents = c
                .get("parents")
                .as_arr()
                .unwrap_or(&[])
                .iter()
                .filter_map(|p| p.as_str().map(String::from))
                .collect::<Vec<_>>();
            let tables = c
                .get("tables")
                .as_obj()
                .map(|o| {
                    o.iter()
                        .filter_map(|(t, s)| s.as_str().map(|s| (t.clone(), s.to_string())))
                        .collect::<BTreeMap<_, _>>()
                })
                .unwrap_or_default();
            let commit = Commit {
                id: id.clone(),
                parents,
                tables,
                author: c.get("author").as_str().unwrap_or("").to_string(),
                message: c.get("message").as_str().unwrap_or("").to_string(),
                run_id: c.get("run_id").as_str().map(String::from),
                timestamp_micros: c.get("timestamp_micros").as_f64().unwrap_or(0.0) as u64,
            };
            commits.push(commit);
        }

        let snapshots_j = json.get("snapshots").as_obj().ok_or_else(|| {
            BauplanError::Parse("catalog export: missing snapshots".into())
        })?;
        let mut snapshots = Vec::new();
        for (id, s) in snapshots_j {
            snapshots.push(Snapshot {
                id: id.clone(),
                objects: s
                    .get("objects")
                    .as_arr()
                    .unwrap_or(&[])
                    .iter()
                    .filter_map(|o| o.as_str().map(String::from))
                    .collect(),
                schema_name: s.get("schema_name").as_str().unwrap_or("").to_string(),
                schema_fingerprint: s
                    .get("schema_fingerprint")
                    .as_str()
                    .unwrap_or("")
                    .to_string(),
                row_count: s.get("row_count").as_f64().unwrap_or(0.0) as u64,
                run_id: s.get("run_id").as_str().unwrap_or("").to_string(),
            });
        }

        let mut branches = Vec::new();
        if let Some(bs) = json.get("branches").as_obj() {
            for (name, b) in bs {
                branches.push(BranchInfo {
                    name: name.clone(),
                    head: b.get("head").as_str().unwrap_or("").to_string(),
                    state: parse_branch_state(b.get("state").as_str().unwrap_or("open"))?,
                    transactional: b.get("transactional").as_bool().unwrap_or(false),
                    owner_run: b.get("owner_run").as_str().map(String::from),
                });
            }
        }
        let mut tags = Vec::new();
        if let Some(ts) = json.get("tags").as_obj() {
            for (name, t) in ts {
                tags.push((name.clone(), t.as_str().unwrap_or("").to_string()));
            }
        }

        cat.restore(commits, snapshots, branches, tags)?;
        Ok(cat)
    }

    /// Reopen a lake persisted with [`Catalog::save`] + a disk store.
    pub fn load(dir: &Path) -> Result<Catalog> {
        let store = Arc::new(ObjectStore::on_disk(dir.join("objects"))?);
        let text = std::fs::read_to_string(dir.join("catalog.json"))?;
        Catalog::import(&Json::parse(&text)?, store)
    }

    /// Save a fully durable lake: catalog.json + all objects on disk.
    /// (If the store is already disk-backed this only writes the json.)
    pub fn save_full(&self, dir: &Path) -> Result<()> {
        std::fs::create_dir_all(dir.join("objects"))?;
        // ensure every reachable object is on disk
        for (_, snap) in self.dump_snapshots() {
            for key in &snap.objects {
                let path = dir.join("objects").join(key);
                if !path.exists() {
                    let data = self.store().get(key)?;
                    std::fs::write(&path, data)?;
                }
            }
        }
        self.save(dir)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::catalog::MAIN;

    fn populated() -> Catalog {
        let c = Catalog::new(Arc::new(ObjectStore::new()));
        let key = c.store().put(vec![1, 2, 3]);
        c.commit_table(
            MAIN,
            "t",
            Snapshot::new(vec![key], "S", "fp", 3, "r1"),
            "u",
            "first",
            Some("r1".into()),
        )
        .unwrap();
        c.create_branch("dev", MAIN, false).unwrap();
        c.tag("v1", MAIN).unwrap();
        c.create_txn_branch(MAIN, "r2").unwrap();
        c.set_branch_state("txn/r2", BranchState::Aborted).unwrap();
        c
    }

    #[test]
    fn export_import_roundtrip() {
        let c = populated();
        let json = c.export();
        let c2 = Catalog::import(&json, c.store().clone()).unwrap();
        assert_eq!(c.export().to_string(), c2.export().to_string());
        // refs behave identically
        assert_eq!(c.resolve(MAIN).unwrap(), c2.resolve(MAIN).unwrap());
        assert_eq!(c.resolve("v1").unwrap(), c2.resolve("v1").unwrap());
        // guardrail state survives
        let b = c2.branch_info("txn/r2").unwrap();
        assert_eq!(b.state, BranchState::Aborted);
        assert!(b.transactional);
    }

    #[test]
    fn export_is_canonical() {
        let c = populated();
        assert_eq!(c.export().to_string(), c.export().to_string());
    }

    #[test]
    fn save_load_from_disk() {
        let dir = std::env::temp_dir().join(format!("bpl_persist_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let c = populated();
        c.save_full(&dir).unwrap();

        let c2 = Catalog::load(&dir).unwrap();
        assert_eq!(c2.resolve(MAIN).unwrap(), c.resolve(MAIN).unwrap());
        // data objects are readable through the disk store
        let head = c2.read_ref(MAIN).unwrap();
        let snap = c2.get_snapshot(&head.tables["t"]).unwrap();
        assert_eq!(c2.store().get(&snap.objects[0]).unwrap(), vec![1, 2, 3]);
        // history intact
        assert_eq!(c2.log(MAIN, 10).unwrap().len(), 2);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn import_rejects_garbage() {
        let store = Arc::new(ObjectStore::new());
        assert!(Catalog::import(&Json::parse("{}").unwrap(), store.clone()).is_err());
        assert!(Catalog::import(&Json::parse(r#"{"commits": {}}"#).unwrap(), store).is_err());
    }
}
