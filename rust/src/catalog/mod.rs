//! Git-for-data catalog (paper §3.2, §4).
//!
//! The paper's claim: "we can reuse Git's mental model for data, if the
//! atomic versioned objects are table snapshots." Concretely:
//!
//! - a [`Snapshot`](snapshot::Snapshot) is an immutable table state
//!   (content-addressed list of data objects + the schema it satisfies);
//! - a [`Commit`](commit::Commit) maps tables to snapshots and points at
//!   parent commits (Listing 7's `tables: Table -> lone Snapshot`);
//! - a branch is a movable ref to a head commit, a tag an immutable one;
//! - **all** lake evolution funnels through [`Catalog::commit`] — the
//!   model's single mutating operation (Listing 8) behind one
//!   [`CommitRequest`]: allocate a fresh snapshot, a fresh commit whose
//!   parent is the observed head, advance the branch. The head is read
//!   and the record prepared *outside* the write lock; validation and
//!   publication happen in a short critical section keyed per branch, so
//!   disjoint-branch committers proceed concurrently — exactly the
//!   optimistic-lock relational-DB transaction real Bauplan delegates to
//!   its catalog (protocol and proofs: `doc/CONCURRENCY.md`).
//!
//! Transactional branches (`txn/<run_id>`) carry extra metadata: their
//! lifecycle state (open / merged / aborted) drives the **visibility
//! guardrail** that the paper's Alloy counterexample (Fig. 4) motivates:
//! forking or merging an *aborted* transactional branch is refused unless
//! the caller passes an explicit `allow_aborted` capability.
//!
//! Durability is layered on without touching the data path: every
//! mutation appends a physical record to the segmented [`journal`]
//! (group commit amortizes the fsync across concurrent committers)
//! before its ref update becomes visible; [`Catalog::checkpoint`]
//! flushes incremental delta snapshots, [`Catalog::compact`] folds them
//! into a base and retires covered journal segments, and
//! [`Catalog::recover`] implements `load(base + deltas) + replay(tail)`
//! crash recovery — tail-bounded, not O(history). The full
//! write/recovery protocol — with the invariant ↔ test mapping — is
//! specified in `doc/COMMIT_PIPELINE.md`.
#![warn(missing_docs)]

pub mod snapshot;
pub mod commit;
mod commit_api;
pub mod refs;
pub mod journal;
pub mod persist;
mod service;

pub use commit::{Commit, CommitId};
pub use commit_api::{CommitOutcome, CommitRequest, RetryPolicy};
pub use journal::{
    CrashPoint, Journal, JournalConfig, JournalOp, JournalRecord, JournalStats, RecoveryStats,
    SyncPolicy, JOURNAL_DIR,
};
pub use refs::{BranchInfo, BranchState, RefName};
pub use service::{Catalog, TableDiff};
pub use snapshot::{Snapshot, SnapshotId};

/// Namespace prefix for transactional branches created by the run engine.
pub const TXN_PREFIX: &str = "txn/";

/// The production branch every catalog starts with.
pub const MAIN: &str = "main";
