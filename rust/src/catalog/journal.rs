//! The durable segmented commit journal (write-ahead log), group commit,
//! and the tail-bounded recovery path.
//!
//! Every catalog mutation appends one canonical-JSON record here *before*
//! its ref update becomes visible to readers (the write-ahead discipline;
//! see `doc/COMMIT_PIPELINE.md` for the full spec). The journal is LSM-
//! shaped: a sequence of **frozen immutable segments** plus one **active
//! tail** under `dir/journal/`, paired with an incremental snapshot chain
//! (base + deltas) under `dir/snapshots/` written by
//! [`Catalog::checkpoint`](crate::catalog::Catalog::checkpoint) and folded
//! by [`Catalog::compact`](crate::catalog::Catalog::compact).
//!
//! - [`Catalog::recover`] reopens a durable lake directory: it loads the
//!   newest base snapshot plus its delta chain, replays only journal
//!   segments *not covered* by the chain, repairs a torn tail (confined to
//!   the active segment), and reattaches the journal. Recovery cost is
//!   O(tail), not O(history) — pinned by `recovery_is_tail_bounded` in
//!   `tests/crash_matrix.rs`.
//! - [`Catalog::checkpoint`](crate::catalog::Catalog::checkpoint) flushes
//!   the in-memory change log as a delta snapshot (memtable → SST), so its
//!   cost is O(changes since last checkpoint).
//! - [`Catalog::compact`](crate::catalog::Catalog::compact) folds base +
//!   deltas into a fresh base, rotates the active segment, and retires
//!   journal segments the new base fully covers.
//!
//! ## Segment format
//!
//! Each segment `dir/journal/seg-<first_seq:020>.jsonl` is a sequence of
//! `\n`-terminated canonical-JSON lines, each carrying a `crc` over the
//! canonical serialization of the rest of the line:
//!
//! | line   | shape                                              | where |
//! |--------|----------------------------------------------------|-------|
//! | header | `{"crc":H,"first_seq":N,"kind":"header","version":1}` | first line of every segment |
//! | record | `{"crc":H,"data":D,"op":O,"seq":N}`                | body |
//! | seal   | `{"crc":H,"kind":"seal","last_seq":N}`             | last line of a *frozen* segment |
//!
//! Sequence numbers are strictly consecutive within and across segments.
//! Records are *physical*: they carry the full commit (including its
//! timestamp) and snapshot payloads, so replay rebuilds byte-identical
//! state without re-running any logic whose output depends on the clock
//! or on merge heuristics.
//!
//! ## Torn tails vs. frozen corruption
//!
//! A crash can leave a partial last line in the **active** segment (and,
//! under batched or group fsync, lose a suffix of records). Recovery
//! applies the longest valid prefix there — the standard WAL prefix rule.
//! If the crash tore the active segment's *own header* (open/rotation
//! died mid-header-write), nothing in the file is valid: recovery removes
//! it and recreates the active tail with a fresh, fsynced header, so an
//! active segment never starts headerless (covered by
//! `torn_active_header_is_recreated_and_acknowledged_appends_survive`).
//! A **frozen** (sealed) segment was fully fsynced before its seal was
//! written; any parse/crc failure inside one is real corruption and fails
//! recovery loudly with an error naming the segment file. Covered by
//! `frozen_segment_corruption_fails_loudly_naming_the_segment` and
//! `torn_tail_is_discarded_and_journal_reusable` in
//! `tests/integration_journal.rs`.

use std::fs::{File, OpenOptions};
use std::io::{Read, Seek, SeekFrom, Write};
use std::path::{Path, PathBuf};
use std::sync::{Arc, Condvar, Mutex};
use std::time::Duration;

use crate::catalog::commit::Commit;
use crate::catalog::persist;
use crate::catalog::refs::{BranchInfo, BranchState};
use crate::catalog::snapshot::Snapshot;
use crate::catalog::Catalog;
use crate::error::{BauplanError, Result};
use crate::storage::ObjectStore;
use crate::util::id::content_hash;
use crate::util::json::Json;

/// Legacy single-file journal name; migrated into a segment on open.
pub const JOURNAL_FILE: &str = "journal.jsonl";

/// Directory (inside a durable lake directory) holding journal segments.
pub const JOURNAL_DIR: &str = "journal";

/// When the journal calls `fsync` relative to appends.
///
/// The append itself always reaches the OS before the mutation becomes
/// visible; the policy only controls when the OS buffer is forced to
/// stable storage.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SyncPolicy {
    /// `fsync` after every append — an acknowledged write is crash-durable.
    EveryAppend,
    /// `fsync` once per `n` appends (group durability). A crash may lose
    /// the unsynced suffix, but recovery still lands on a consistent
    /// prefix state. [`Catalog::journal_sync`] forces a flush.
    Batch(u64),
    /// Group commit: concurrent committers enqueue their records and one
    /// *leader* fsyncs the whole batch; every committer blocks until a
    /// sync covers its record, so an acknowledged write is crash-durable
    /// — with the sync cost amortized across the batch. The default for
    /// [`Catalog::recover`].
    GroupCommit,
}

impl Default for SyncPolicy {
    fn default() -> Self {
        SyncPolicy::GroupCommit
    }
}

/// Tunables for the segmented journal, beyond the [`SyncPolicy`].
#[derive(Debug, Clone, Copy)]
pub struct JournalConfig {
    /// Fsync policy for appends.
    pub sync: SyncPolicy,
    /// Rotate the active segment before an append would push it past this
    /// many bytes. Rotation happens *before* the append, so a record
    /// never straddles segments.
    pub segment_bytes: u64,
    /// `checkpoint()` promotes itself to a full [`Catalog::compact`] once
    /// this many deltas have accumulated since the last base.
    pub compact_after_deltas: u64,
    /// Artificial latency added before every data fsync, in microseconds.
    /// Benches use this to model a disk with a stable sync cost, making
    /// the group-commit amortization measurable deterministically; 0 in
    /// production.
    pub sync_latency_micros: u64,
}

impl Default for JournalConfig {
    fn default() -> Self {
        JournalConfig {
            sync: SyncPolicy::default(),
            segment_bytes: 4 * 1024 * 1024,
            compact_after_deltas: 16,
            sync_latency_micros: 0,
        }
    }
}

impl JournalConfig {
    /// Default config with an explicit sync policy.
    pub fn with_sync(sync: SyncPolicy) -> JournalConfig {
        JournalConfig { sync, ..JournalConfig::default() }
    }
}

/// Counters exposed for benches and tests.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct JournalStats {
    /// Records appended through this handle.
    pub appends: u64,
    /// `fsync` calls issued (data syncs; group-commit leader syncs
    /// included).
    pub syncs: u64,
    /// Bytes written (journal lines only).
    pub bytes_written: u64,
    /// Highest sequence number ever assigned (0 = none).
    pub last_seq: u64,
    /// Segment rotations performed through this handle.
    pub rotations: u64,
}

/// What recovery actually read — the evidence for the tail-bounded claim.
///
/// Exposed by [`Catalog::recovery_stats`]; asserted by
/// `recovery_is_tail_bounded` in `tests/crash_matrix.rs`.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct RecoveryStats {
    /// Journal segments whose records were scanned and replayed.
    pub segments_scanned: u64,
    /// Journal segments skipped because the snapshot chain covers them
    /// entirely (identified by file name alone — zero bytes read).
    pub segments_skipped: u64,
    /// Journal records replayed on top of the snapshot chain.
    pub records_replayed: u64,
    /// Bytes read from journal segments during recovery.
    pub bytes_scanned: u64,
    /// Journal floor of the base snapshot loaded (0 = none).
    pub base_seq: u64,
    /// Delta snapshots applied on top of the base.
    pub deltas_loaded: u64,
}

/// Kill points enumerated by the crash-matrix harness
/// (`crate::testing::crash`). Arming one via
/// [`Catalog::inject_crash_point`] makes the next operation that reaches
/// the point fail as if the process died there, and poisons the journal so
/// every later append fails too — the lake must then be reopened with
/// [`Catalog::recover`].
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum CrashPoint {
    /// Die halfway through writing a record line (torn tail in the active
    /// segment).
    MidRecord,
    /// Die during rotation, after the old segment was sealed and synced
    /// but before the fresh active segment exists.
    AtRotationSealed,
    /// Die during `checkpoint()`, after the journal is synced but before
    /// the delta snapshot file is atomically published.
    MidDeltaFlush,
    /// Die during `compact()`, right after the new base snapshot is
    /// published — stale bases/deltas and all journal segments survive.
    MidCompactBase,
    /// Die during `compact()`, after the rotation but before covered
    /// segments are retired.
    MidCompactRetire,
}

impl CrashPoint {
    /// Every kill point, for matrix enumeration.
    pub const ALL: [CrashPoint; 5] = [
        CrashPoint::MidRecord,
        CrashPoint::AtRotationSealed,
        CrashPoint::MidDeltaFlush,
        CrashPoint::MidCompactBase,
        CrashPoint::MidCompactRetire,
    ];
}

/// One journaled mutation. Records are physical: they carry the exact
/// commits/snapshots/branch metadata the mutation produced, so replay is
/// deterministic and byte-exact.
#[derive(Debug, Clone, PartialEq)]
pub enum JournalOp {
    /// A new commit advanced `branch` (covers `Catalog::commit` under
    /// every retry policy, `delete_table`, and three-way merge commits).
    /// `snapshot` is the snapshot the commit introduced, if any.
    Commit {
        /// Branch whose head advanced.
        branch: String,
        /// The full new commit (timestamp included).
        commit: Commit,
        /// Snapshot registered together with the commit, if any.
        snapshot: Option<Snapshot>,
    },
    /// A rebase/cherry-pick applied a batch of commits atomically
    /// (`apply_deltas`): all commits insert and the head moves to the
    /// last one — one record, so the batch is all-or-nothing on disk.
    Replay {
        /// Branch whose head advanced.
        branch: String,
        /// Commits in application order; head lands on the last.
        commits: Vec<Commit>,
    },
    /// A branch was created (normal or transactional).
    BranchCreate {
        /// The full branch metadata at creation.
        info: BranchInfo,
    },
    /// A transactional branch changed lifecycle state.
    SetBranchState {
        /// Branch name.
        name: String,
        /// New lifecycle state.
        state: BranchState,
    },
    /// A branch was deleted.
    BranchDelete {
        /// Branch name.
        name: String,
    },
    /// A tag was created.
    Tag {
        /// Tag name.
        name: String,
        /// Commit id the tag pins.
        target: String,
    },
    /// A branch head moved to an existing commit without a new commit
    /// (fast-forward merge, rebase epilogue).
    Head {
        /// Branch whose head moved.
        branch: String,
        /// Commit id it now points at.
        commit: String,
    },
    /// A snapshot was registered ahead of its commit (`register_snapshot`).
    RegisterSnapshot {
        /// The full snapshot.
        snapshot: Snapshot,
    },
    /// Garbage collection ran. The record carries the pinned-snapshot
    /// roots the sweep used (pins are not otherwise journaled), so
    /// replay re-runs the identical deterministic mark-and-sweep and
    /// recovered state matches the post-gc export.
    Gc {
        /// Pinned-snapshot GC roots at sweep time, sorted.
        pins: Vec<String>,
    },
    /// A run reached a terminal state. The record is opaque JSON owned
    /// by the run engine (`runs::RunState` codec) — the catalog journals
    /// and checkpoints it so `get_run` survives process restarts.
    RunRecord {
        /// The run id the record describes.
        run_id: String,
        /// The run engine's serialized terminal state.
        record: crate::util::json::Json,
    },
    /// The span trace of a terminal run. Opaque JSON owned by the
    /// tracing layer (`trace::Trace::to_json`, capped and
    /// truncation-counted there) — journaled beside the run record so
    /// `bauplan trace <run-id>` survives process restarts.
    RunTrace {
        /// The run id the trace belongs to.
        run_id: String,
        /// The serialized span trace.
        trace: crate::util::json::Json,
    },
}

/// A sequenced journal record.
#[derive(Debug, Clone, PartialEq)]
pub struct JournalRecord {
    /// Strictly increasing sequence number (1-based).
    pub seq: u64,
    /// The mutation.
    pub op: JournalOp,
}

/// Serialize a canonical body, splice the crc in front. Canonical key
/// order puts "crc" first ("crc" < "data"/"first_seq"/"kind"), so the crc
/// field can be spliced into the already-serialized body rather than
/// building the tree twice — this runs under the catalog write lock on
/// every mutation.
fn crc_line(body: &Json) -> String {
    let body = body.to_string();
    let crc = content_hash(body.as_bytes());
    format!("{{\"crc\":\"{crc}\",{}\n", &body[1..])
}

/// Verify the `crc` field of a parsed line against the canonical
/// serialization of its remaining fields.
pub(crate) fn crc_ok(v: &Json) -> bool {
    let (crc, rest) = match v.as_obj() {
        Some(obj) => {
            let crc = match obj.get("crc").and_then(|c| c.as_str()) {
                Some(c) => c.to_string(),
                None => return false,
            };
            let mut rest = obj.clone();
            rest.remove("crc");
            (crc, Json::Obj(rest))
        }
        None => return false,
    };
    content_hash(rest.to_string().as_bytes()) == crc
}

/// The header line opening segment `first_seq`.
fn header_line(first_seq: u64) -> String {
    crc_line(&Json::obj(vec![
        ("first_seq", Json::num(first_seq as f64)),
        ("kind", Json::str("header")),
        ("version", Json::num(1.0)),
    ]))
}

/// The seal line freezing a segment whose last record is `last_seq`.
fn seal_line(last_seq: u64) -> String {
    crc_line(&Json::obj(vec![
        ("kind", Json::str("seal")),
        ("last_seq", Json::num(last_seq as f64)),
    ]))
}

/// File name of the segment whose first record is `first_seq`.
pub(crate) fn segment_name(first_seq: u64) -> String {
    format!("seg-{first_seq:020}.jsonl")
}

/// Parse `seg-<first_seq>.jsonl` back to its first sequence number.
pub(crate) fn parse_segment_name(name: &str) -> Option<u64> {
    let digits = name.strip_prefix("seg-")?.strip_suffix(".jsonl")?;
    if digits.len() != 20 || !digits.bytes().all(|b| b.is_ascii_digit()) {
        return None;
    }
    digits.parse().ok()
}

/// One parsed segment line.
pub(crate) enum SegLine {
    /// The header opening a segment (`first_seq` names the file).
    Header {
        /// First record sequence the segment holds.
        first_seq: u64,
    },
    /// A sequenced journal record.
    Record(JournalRecord),
    /// The seal freezing a segment after its last record.
    Seal {
        /// Last record sequence the sealed segment holds.
        last_seq: u64,
    },
}

/// Parse and crc-check one segment line (header, record, or seal).
pub(crate) fn parse_seg_line(line: &str) -> Result<SegLine> {
    let v = Json::parse(line)?;
    if !crc_ok(&v) {
        return Err(BauplanError::Parse("segment line: crc mismatch".into()));
    }
    match v.get("kind").as_str() {
        Some("header") => {
            let first_seq = v
                .get("first_seq")
                .as_f64()
                .ok_or_else(|| BauplanError::Parse("segment header: missing first_seq".into()))?
                as u64;
            Ok(SegLine::Header { first_seq })
        }
        Some("seal") => {
            let last_seq = v
                .get("last_seq")
                .as_f64()
                .ok_or_else(|| BauplanError::Parse("segment seal: missing last_seq".into()))?
                as u64;
            Ok(SegLine::Seal { last_seq })
        }
        Some(other) => Err(BauplanError::Parse(format!("segment line: unknown kind '{other}'"))),
        None => Ok(SegLine::Record(JournalRecord::from_line(line)?)),
    }
}

impl JournalOp {
    /// The record's wire tag — also the `op` attribute on the flight
    /// recorder's `catalog.journal_append` spans.
    pub(crate) fn name(&self) -> &'static str {
        match self {
            JournalOp::Commit { .. } => "commit",
            JournalOp::Replay { .. } => "replay",
            JournalOp::BranchCreate { .. } => "branch_create",
            JournalOp::SetBranchState { .. } => "branch_state",
            JournalOp::BranchDelete { .. } => "branch_delete",
            JournalOp::Tag { .. } => "tag",
            JournalOp::Head { .. } => "head",
            JournalOp::RegisterSnapshot { .. } => "snapshot",
            JournalOp::Gc { .. } => "gc",
            JournalOp::RunRecord { .. } => "run_record",
            JournalOp::RunTrace { .. } => "run_trace",
        }
    }

    fn data_json(&self) -> Json {
        match self {
            JournalOp::Commit { branch, commit, snapshot } => Json::obj(vec![
                ("branch", Json::str(branch)),
                ("commit_id", Json::str(&commit.id)),
                ("commit", persist::commit_to_json(commit)),
                (
                    "snapshot_id",
                    snapshot.as_ref().map(|s| Json::str(&s.id)).unwrap_or(Json::Null),
                ),
                (
                    "snapshot",
                    snapshot.as_ref().map(persist::snapshot_to_json).unwrap_or(Json::Null),
                ),
            ]),
            JournalOp::Replay { branch, commits } => Json::obj(vec![
                ("branch", Json::str(branch)),
                (
                    "commits",
                    Json::Arr(
                        commits
                            .iter()
                            .map(|c| {
                                Json::obj(vec![
                                    ("commit_id", Json::str(&c.id)),
                                    ("commit", persist::commit_to_json(c)),
                                ])
                            })
                            .collect(),
                    ),
                ),
            ]),
            JournalOp::BranchCreate { info } => Json::obj(vec![
                ("name", Json::str(&info.name)),
                ("branch", persist::branch_to_json(info)),
            ]),
            JournalOp::SetBranchState { name, state } => Json::obj(vec![
                ("name", Json::str(name)),
                ("state", Json::str(persist::branch_state_str(*state))),
            ]),
            JournalOp::BranchDelete { name } => {
                Json::obj(vec![("name", Json::str(name))])
            }
            JournalOp::Tag { name, target } => Json::obj(vec![
                ("name", Json::str(name)),
                ("target", Json::str(target)),
            ]),
            JournalOp::Head { branch, commit } => Json::obj(vec![
                ("branch", Json::str(branch)),
                ("commit", Json::str(commit)),
            ]),
            JournalOp::RegisterSnapshot { snapshot } => Json::obj(vec![
                ("snapshot_id", Json::str(&snapshot.id)),
                ("snapshot", persist::snapshot_to_json(snapshot)),
            ]),
            JournalOp::Gc { pins } => Json::obj(vec![(
                "pins",
                Json::Arr(pins.iter().map(Json::str).collect()),
            )]),
            JournalOp::RunRecord { run_id, record } => Json::obj(vec![
                ("run_id", Json::str(run_id)),
                ("record", record.clone()),
            ]),
            JournalOp::RunTrace { run_id, trace } => Json::obj(vec![
                ("run_id", Json::str(run_id)),
                ("trace", trace.clone()),
            ]),
        }
    }

    /// Serialize as one canonical journal line at sequence `seq`.
    fn to_line(&self, seq: u64) -> String {
        crc_line(&Json::obj(vec![
            ("data", self.data_json()),
            ("op", Json::str(self.name())),
            ("seq", Json::num(seq as f64)),
        ]))
    }
}

impl JournalRecord {
    /// Serialize to one canonical journal line (`\n`-terminated).
    pub fn to_line(&self) -> String {
        self.op.to_line(self.seq)
    }

    /// Parse and integrity-check one journal record line (without the
    /// trailing newline). Fails on malformed JSON, a crc mismatch, or an
    /// unknown op.
    pub fn from_line(line: &str) -> Result<JournalRecord> {
        let v = Json::parse(line)?;
        let crc = v
            .get("crc")
            .as_str()
            .ok_or_else(|| BauplanError::Parse("journal record: missing crc".into()))?
            .to_string();
        let seq = v
            .get("seq")
            .as_f64()
            .ok_or_else(|| BauplanError::Parse("journal record: missing seq".into()))?
            as u64;
        let op_name = v
            .get("op")
            .as_str()
            .ok_or_else(|| BauplanError::Parse("journal record: missing op".into()))?
            .to_string();
        let data = v.get("data").clone();
        // verify the crc over the canonical {data, op, seq} serialization
        let inner = Json::obj(vec![
            ("data", data.clone()),
            ("op", Json::str(&op_name)),
            ("seq", Json::num(seq as f64)),
        ]);
        if content_hash(inner.to_string().as_bytes()) != crc {
            return Err(BauplanError::Parse(format!(
                "journal record seq {seq}: crc mismatch"
            )));
        }
        let str_field = |j: &Json, k: &str| -> Result<String> {
            j.get(k)
                .as_str()
                .map(String::from)
                .ok_or_else(|| BauplanError::Parse(format!("journal record: missing {k}")))
        };
        let op = match op_name.as_str() {
            "commit" => {
                let branch = str_field(&data, "branch")?;
                let id = str_field(&data, "commit_id")?;
                let commit = persist::commit_from_json(&id, data.get("commit"));
                let snapshot = match data.get("snapshot_id").as_str() {
                    Some(sid) => {
                        Some(persist::snapshot_from_json(sid, data.get("snapshot")))
                    }
                    None => None,
                };
                JournalOp::Commit { branch, commit, snapshot }
            }
            "replay" => {
                let branch = str_field(&data, "branch")?;
                let mut commits = Vec::new();
                for cj in data.get("commits").as_arr().unwrap_or(&[]) {
                    let id = str_field(cj, "commit_id")?;
                    commits.push(persist::commit_from_json(&id, cj.get("commit")));
                }
                if commits.is_empty() {
                    return Err(BauplanError::Parse(
                        "journal record: replay with no commits".into(),
                    ));
                }
                JournalOp::Replay { branch, commits }
            }
            "branch_create" => {
                let name = str_field(&data, "name")?;
                let info = persist::branch_from_json(&name, data.get("branch"))?;
                JournalOp::BranchCreate { info }
            }
            "branch_state" => JournalOp::SetBranchState {
                name: str_field(&data, "name")?,
                state: persist::parse_branch_state(&str_field(&data, "state")?)?,
            },
            "branch_delete" => JournalOp::BranchDelete { name: str_field(&data, "name")? },
            "tag" => JournalOp::Tag {
                name: str_field(&data, "name")?,
                target: str_field(&data, "target")?,
            },
            "head" => JournalOp::Head {
                branch: str_field(&data, "branch")?,
                commit: str_field(&data, "commit")?,
            },
            "snapshot" => {
                let sid = str_field(&data, "snapshot_id")?;
                JournalOp::RegisterSnapshot {
                    snapshot: persist::snapshot_from_json(&sid, data.get("snapshot")),
                }
            }
            // lenient on `pins`: pre-cache records carried no data
            "gc" => JournalOp::Gc {
                pins: data
                    .get("pins")
                    .as_arr()
                    .unwrap_or(&[])
                    .iter()
                    .filter_map(|p| p.as_str().map(String::from))
                    .collect(),
            },
            "run_record" => JournalOp::RunRecord {
                run_id: str_field(&data, "run_id")?,
                record: data.get("record").clone(),
            },
            "run_trace" => JournalOp::RunTrace {
                run_id: str_field(&data, "run_id")?,
                trace: data.get("trace").clone(),
            },
            other => {
                return Err(BauplanError::Parse(format!(
                    "journal record: unknown op '{other}'"
                )))
            }
        };
        Ok(JournalRecord { seq, op })
    }
}

// ---------------------------------------------------------------------------
// Group commit
// ---------------------------------------------------------------------------

/// Shared group-commit state: which sequence numbers have been appended to
/// the active segment and which a data fsync already covers.
struct GroupState {
    /// Active segment file handle (shared so the leader can sync outside
    /// the catalog locks). `None` only after a rotation crash poisoned
    /// the journal.
    file: Option<Arc<File>>,
    /// Highest sequence number appended to the active segment.
    appended_seq: u64,
    /// Active segment length (bytes) after the last append.
    appended_bytes: u64,
    /// Highest sequence number a completed fsync covers.
    synced_seq: u64,
    /// Active segment length (bytes) a completed fsync covers.
    synced_bytes: u64,
    /// Which active segment the byte counters describe (its `first_seq`).
    /// A leader fsyncs outside the catalog locks, so a rotation can land
    /// mid-sync: the leader must then skip its byte-counter merge — the
    /// bytes it synced belong to the previous (now frozen) segment and
    /// would inflate `synced_bytes` past the new segment's real extent.
    epoch: u64,
    /// A leader is currently fsyncing.
    leader_running: bool,
    /// A leader's fsync failed: the journal is poisoned and every waiter
    /// errors.
    failed: bool,
    /// Debug hook: make the next leader fsync fail (consumed once), so
    /// tests can exercise the poison path without a real disk fault.
    fail_next_sync: bool,
    /// Leader fsyncs completed (folded into [`JournalStats::syncs`]).
    syncs: u64,
    /// Artificial sync latency (from [`JournalConfig`]).
    sync_latency_micros: u64,
}

/// Condvar-guarded [`GroupState`], shared between the journal (held under
/// the catalog's durability lock) and committers waiting on a ticket.
pub(crate) struct GroupSync {
    state: Mutex<GroupState>,
    cv: Condvar,
}

/// What a committer holds after its record was appended: proof of
/// durability, or a claim ticket it must wait on.
///
/// Returned (crate-internally) by the catalog's journal append; the
/// mutator applies its in-memory change, releases the catalog locks, and
/// then waits — so the fsync of one batch overlaps the appends of the
/// next.
pub(crate) enum SyncTicket {
    /// The record is already durable (or durability is not required by
    /// the policy).
    Done,
    /// Group commit: wait until a leader's fsync covers `seq`.
    Group { seq: u64, sync: Arc<GroupSync> },
}

impl SyncTicket {
    /// Block until the record is durable. In the group protocol, the
    /// first waiter to find no leader running becomes the leader: it
    /// fsyncs everything appended so far, marks the covered range, and
    /// wakes every waiter.
    pub(crate) fn wait(self) -> Result<()> {
        let (seq, sync) = match self {
            SyncTicket::Done => return Ok(()),
            SyncTicket::Group { seq, sync } => (seq, sync),
        };
        let mut st = sync.state.lock().unwrap();
        loop {
            if st.failed {
                return Err(BauplanError::Poisoned(
                    "a group-commit leader fsync failed; reopen with Catalog::recover".into(),
                ));
            }
            if st.synced_seq >= seq {
                return Ok(());
            }
            if !st.leader_running {
                // become the leader: sync everything appended so far
                let file = match st.file.clone() {
                    Some(f) => f,
                    None => {
                        return Err(BauplanError::Io(std::io::Error::new(
                            std::io::ErrorKind::Other,
                            "group commit: journal poisoned",
                        )))
                    }
                };
                let target_seq = st.appended_seq;
                let target_bytes = st.appended_bytes;
                let epoch = st.epoch;
                let latency = st.sync_latency_micros;
                let inject_fail = std::mem::take(&mut st.fail_next_sync);
                st.leader_running = true;
                drop(st);
                if latency > 0 {
                    std::thread::sleep(Duration::from_micros(latency));
                }
                let res = if inject_fail {
                    Err(std::io::Error::new(
                        std::io::ErrorKind::Other,
                        "injected group-commit fsync failure",
                    ))
                } else {
                    file.sync_data()
                };
                st = sync.state.lock().unwrap();
                st.leader_running = false;
                match res {
                    Ok(()) => {
                        st.synced_seq = st.synced_seq.max(target_seq);
                        if st.epoch == epoch {
                            // a rotation during the fsync froze the segment
                            // these bytes belong to; the new segment's
                            // counters are already exact
                            st.synced_bytes = st.synced_bytes.max(target_bytes);
                        }
                        st.syncs += 1;
                    }
                    Err(e) => {
                        st.failed = true;
                        sync.cv.notify_all();
                        return Err(BauplanError::Io(e));
                    }
                }
                sync.cv.notify_all();
                continue;
            }
            st = sync.cv.wait(st).unwrap();
        }
    }
}

// ---------------------------------------------------------------------------
// Journal handle
// ---------------------------------------------------------------------------

/// What scanning the segment directory produced.
pub(crate) struct JournalScan {
    /// Records with `seq > floor`, in order.
    pub records: Vec<JournalRecord>,
    /// Segment-level recovery evidence (base/delta fields left zero).
    pub stats: RecoveryStats,
}

/// The segmented append-only journal handle.
///
/// Owned by the catalog's durability slot and driven only while the
/// catalog's durability lock is held, so appends are totally ordered and
/// sequence numbers never race. Under [`SyncPolicy::GroupCommit`] the
/// fsync itself happens *outside* those locks, through [`SyncTicket`].
pub struct Journal {
    /// `dir/journal` — the segment directory.
    seg_dir: PathBuf,
    /// Active segment file (shared with the group-commit leader path).
    file: Option<Arc<File>>,
    /// First sequence number of the active segment (names its file).
    active_first_seq: u64,
    /// Current byte length of the active segment.
    active_bytes: u64,
    /// Byte length of the active segment covered by a data fsync
    /// (non-group policies; the group path tracks its own in
    /// [`GroupState`]).
    synced_bytes: u64,
    next_seq: u64,
    config: JournalConfig,
    unsynced: u64,
    stats: JournalStats,
    group: Arc<GroupSync>,
    /// Fail the (n+1)-th append from now — crash-point injection for the
    /// write-ahead-discipline tests.
    fail_after: Option<u64>,
    /// Armed kill point for the crash matrix; tripping it poisons the
    /// journal (`fail_after = 0`).
    crash_point: Option<CrashPoint>,
}

impl Journal {
    /// Open (or create) the segmented journal under `dir/journal`, scan
    /// every non-covered segment, repair a torn active tail, and return
    /// the handle plus every valid record with `seq > floor_seq`.
    ///
    /// `floor_seq` is the snapshot chain's last covered sequence number:
    /// segments whose records all fall at or below it are *skipped by
    /// file name alone* (their successor's `first_seq` proves coverage),
    /// which is what makes recovery O(tail). A legacy single-file
    /// `dir/journal.jsonl` is migrated into the first segment.
    pub(crate) fn open(
        dir: &Path,
        config: JournalConfig,
        floor_seq: u64,
    ) -> Result<(Journal, JournalScan)> {
        let seg_dir = dir.join(JOURNAL_DIR);
        std::fs::create_dir_all(&seg_dir)?;
        migrate_legacy_journal(dir, &seg_dir)?;

        // enumerate segments by name, sorted by first_seq
        let mut segs: Vec<(u64, PathBuf)> = Vec::new();
        for entry in std::fs::read_dir(&seg_dir)? {
            let entry = entry?;
            let name = entry.file_name().to_string_lossy().to_string();
            if let Some(first) = parse_segment_name(&name) {
                segs.push((first, entry.path()));
            }
        }
        segs.sort_by_key(|(first, _)| *first);

        let mut stats = RecoveryStats::default();
        let mut records: Vec<JournalRecord> = Vec::new();
        let mut max_seq = floor_seq;
        let mut active: Option<(u64, PathBuf, u64, u64)> = None; // first_seq, path, len, synced

        let last_idx = segs.len().wrapping_sub(1);
        for (i, (first_seq, path)) in segs.iter().enumerate() {
            let is_last = i == last_idx;
            // a frozen segment's full extent is [first_seq, next.first_seq)
            // — if the successor starts at or below floor+1, every record
            // here is covered by the snapshot chain: skip by name alone
            if !is_last {
                let next_first = segs[i + 1].0;
                if next_first <= floor_seq + 1 {
                    stats.segments_skipped += 1;
                    max_seq = max_seq.max(next_first - 1);
                    continue;
                }
            }
            let frozen = !is_last;
            let scan = scan_segment(path, *first_seq, frozen)?;
            stats.segments_scanned += 1;
            stats.bytes_scanned += scan.bytes;
            if let Some(last) = scan.records.last() {
                max_seq = max_seq.max(last.seq);
            }
            if frozen && !scan.sealed {
                // only the newest segment may be unsealed: an unsealed
                // middle segment means rotation's ordering was violated
                return Err(BauplanError::Parse(format!(
                    "journal segment {} is not sealed but has a successor",
                    path.display()
                )));
            }
            for rec in scan.records {
                if rec.seq > floor_seq {
                    records.push(rec);
                }
            }
            if is_last {
                if scan.sealed {
                    // the newest segment is already frozen (clean shutdown
                    // right after rotation/compaction): start a fresh
                    // active segment after it
                    active = None;
                } else if scan.valid_end == 0 {
                    // the active segment's own header never made it down
                    // whole (crash during the header write of open or
                    // rotation, or an empty just-created file). Nothing in
                    // it is valid, so remove it and recreate the active
                    // tail below with a fresh, fsynced header — truncating
                    // to 0 and reattaching would produce a headerless
                    // segment whose later (acknowledged!) appends the next
                    // recovery must throw away at "record before header".
                    std::fs::remove_file(path)?;
                    sync_dir(&seg_dir);
                    active = None;
                } else {
                    if scan.valid_end < scan.bytes {
                        // torn tail in the active segment: truncate to the
                        // longest valid prefix (the WAL prefix rule)
                        let f = OpenOptions::new().write(true).open(path)?;
                        f.set_len(scan.valid_end)?;
                        f.sync_data()?;
                    }
                    active = Some((*first_seq, path.clone(), scan.valid_end, scan.valid_end));
                }
            }
        }
        stats.records_replayed = records.len() as u64;

        let next_seq = max_seq + 1;
        let (active_first_seq, active_path, active_bytes, synced_bytes) = match active {
            Some(a) => a,
            None => {
                // fresh active segment (new lake, or newest segment sealed)
                let path = seg_dir.join(segment_name(next_seq));
                let header = header_line(next_seq);
                let mut f = OpenOptions::new().create(true).write(true).open(&path)?;
                f.set_len(0)?;
                f.write_all(header.as_bytes())?;
                f.sync_data()?;
                sync_dir(&seg_dir);
                (next_seq, path, header.len() as u64, header.len() as u64)
            }
        };
        let mut file = OpenOptions::new().read(true).write(true).open(&active_path)?;
        file.seek(SeekFrom::End(0))?;
        let file = Arc::new(file);

        let group = Arc::new(GroupSync {
            state: Mutex::new(GroupState {
                file: Some(file.clone()),
                appended_seq: max_seq,
                appended_bytes: active_bytes,
                synced_seq: max_seq,
                synced_bytes,
                epoch: active_first_seq,
                leader_running: false,
                failed: false,
                fail_next_sync: false,
                syncs: 0,
                sync_latency_micros: config.sync_latency_micros,
            }),
            cv: Condvar::new(),
        });

        let jstats = JournalStats { last_seq: max_seq, ..JournalStats::default() };
        Ok((
            Journal {
                seg_dir,
                file: Some(file),
                active_first_seq,
                active_bytes,
                synced_bytes,
                next_seq,
                config,
                unsynced: 0,
                stats: jstats,
                group,
                fail_after: None,
                crash_point: None,
            },
            JournalScan { records, stats },
        ))
    }

    /// Append one record; returns its sequence number plus the sync
    /// ticket the committer must wait on *after* releasing the catalog
    /// locks. The bytes are written (and, for non-group policies, synced
    /// per [`SyncPolicy`]) before this returns — the caller applies the
    /// in-memory mutation only afterwards.
    pub(crate) fn append(&mut self, op: &JournalOp) -> Result<(u64, SyncTicket)> {
        self.check_fail()?;
        if matches!(self.config.sync, SyncPolicy::GroupCommit)
            && self.group.state.lock().unwrap().failed
        {
            // a leader fsync already failed: refuse new appends instead of
            // growing in-memory state the journal cannot make durable
            return Err(BauplanError::Poisoned(
                "a group-commit leader fsync failed; reopen with Catalog::recover".into(),
            ));
        }
        let seq = self.next_seq;
        let line = op.to_line(seq);

        // rotate-before-append: a record never straddles segments, and a
        // rotation crash can only lose the not-yet-appended record
        if self.active_bytes + line.len() as u64 > self.config.segment_bytes
            && self.next_seq > self.active_first_seq
        {
            self.rotate()?;
        }

        let file = self.file_handle()?;
        if self.crash_armed(CrashPoint::MidRecord) {
            // die halfway through the write: a torn line in the active tail
            let half = line.len() / 2;
            let _ = (&*file).write_all(&line.as_bytes()[..half]);
            let _ = file.sync_data();
            return Err(self.trip_crash());
        }
        (&*file).write_all(line.as_bytes())?;
        self.next_seq += 1;
        self.active_bytes += line.len() as u64;
        self.stats.appends += 1;
        self.stats.bytes_written += line.len() as u64;
        self.stats.last_seq = seq;
        let ticket = match self.config.sync {
            SyncPolicy::EveryAppend => {
                self.sync_data(&file)?;
                self.stats.syncs += 1;
                self.synced_bytes = self.active_bytes;
                SyncTicket::Done
            }
            SyncPolicy::Batch(n) => {
                self.unsynced += 1;
                if self.unsynced >= n.max(1) {
                    self.sync_data(&file)?;
                    self.stats.syncs += 1;
                    self.unsynced = 0;
                    self.synced_bytes = self.active_bytes;
                }
                SyncTicket::Done
            }
            SyncPolicy::GroupCommit => {
                let mut st = self.group.state.lock().unwrap();
                st.appended_seq = seq;
                st.appended_bytes = self.active_bytes;
                drop(st);
                SyncTicket::Group { seq, sync: self.group.clone() }
            }
        };
        Ok((seq, ticket))
    }

    /// Seal the active segment and open a fresh one starting at
    /// `next_seq`. Ordering: sync old data → append + sync seal → create
    /// + sync new segment header → fsync directory → swap the live
    /// handle. A crash anywhere leaves either a valid active tail or a
    /// sealed segment with no successor (recovery then opens a fresh
    /// active segment).
    fn rotate(&mut self) -> Result<()> {
        let file = self.file_handle()?;
        let last = self.next_seq - 1;
        // everything in the old segment must be durable before the seal
        // claims it is frozen
        self.sync_data(&file)?;
        let seal = seal_line(last);
        (&*file).write_all(seal.as_bytes())?;
        self.sync_data(&file)?;
        self.stats.syncs += 2;
        self.stats.bytes_written += seal.len() as u64;

        if self.crash_armed(CrashPoint::AtRotationSealed) {
            // sealed, synced — but the fresh active segment never appears
            let mut st = self.group.state.lock().unwrap();
            st.file = None;
            drop(st);
            self.file = None;
            return Err(self.trip_crash());
        }

        let path = self.seg_dir.join(segment_name(self.next_seq));
        let header = header_line(self.next_seq);
        let mut f = OpenOptions::new().create(true).read(true).write(true).open(&path)?;
        f.set_len(0)?;
        f.write_all(header.as_bytes())?;
        f.sync_data()?;
        sync_dir(&self.seg_dir);
        f.seek(SeekFrom::End(0))?;
        let f = Arc::new(f);

        self.active_first_seq = self.next_seq;
        self.active_bytes = header.len() as u64;
        self.synced_bytes = self.active_bytes;
        self.unsynced = 0;
        self.stats.rotations += 1;
        self.file = Some(f.clone());
        let mut st = self.group.state.lock().unwrap();
        // the old segment is fully synced; the new one starts clean
        st.file = Some(f);
        st.synced_seq = last;
        st.synced_bytes = header.len() as u64;
        st.appended_bytes = header.len() as u64;
        st.epoch = self.active_first_seq;
        Ok(())
    }

    /// Seal the active segment and start a fresh one, if it holds at
    /// least one record. Used by `compact()` so the snapshot floor can
    /// cover (and retire) everything written so far.
    pub(crate) fn rotate_if_nonempty(&mut self) -> Result<()> {
        if self.next_seq > self.active_first_seq {
            self.rotate()?;
        }
        Ok(())
    }

    /// Delete frozen segments every record of which is `<= covered`
    /// (proven by the successor segment's `first_seq`). The active
    /// segment is never deleted. Returns how many were retired.
    pub(crate) fn retire_covered(&mut self, covered: u64) -> Result<u64> {
        let mut segs: Vec<(u64, PathBuf)> = Vec::new();
        for entry in std::fs::read_dir(&self.seg_dir)? {
            let entry = entry?;
            let name = entry.file_name().to_string_lossy().to_string();
            if let Some(first) = parse_segment_name(&name) {
                segs.push((first, entry.path()));
            }
        }
        segs.sort_by_key(|(first, _)| *first);
        let mut retired = 0;
        for i in 0..segs.len() {
            let (first, ref path) = segs[i];
            if first == self.active_first_seq {
                break; // never the active segment
            }
            let next_first = match segs.get(i + 1) {
                Some((nf, _)) => *nf,
                None => break,
            };
            if next_first <= covered + 1 {
                std::fs::remove_file(path)?;
                retired += 1;
            }
        }
        if retired > 0 {
            sync_dir(&self.seg_dir);
        }
        Ok(retired)
    }

    /// Force any batched/grouped appends to stable storage.
    pub fn sync(&mut self) -> Result<()> {
        match self.config.sync {
            SyncPolicy::EveryAppend => Ok(()),
            SyncPolicy::Batch(_) => {
                let file = self.file_handle()?;
                self.sync_data(&file)?;
                self.stats.syncs += 1;
                self.unsynced = 0;
                self.synced_bytes = self.active_bytes;
                Ok(())
            }
            SyncPolicy::GroupCommit => {
                if self.group.state.lock().unwrap().failed {
                    return Err(BauplanError::Poisoned(
                        "a group-commit leader fsync failed; reopen with Catalog::recover"
                            .into(),
                    ));
                }
                let file = self.file_handle()?;
                self.sync_data(&file)?;
                self.stats.syncs += 1;
                let mut st = self.group.state.lock().unwrap();
                st.synced_seq = st.synced_seq.max(self.next_seq - 1);
                st.synced_bytes = st.synced_bytes.max(self.active_bytes);
                drop(st);
                self.group.cv.notify_all();
                Ok(())
            }
        }
    }

    /// Highest sequence number assigned so far (0 = none).
    pub fn last_seq(&self) -> u64 {
        self.next_seq - 1
    }

    /// The configuration this handle was opened with.
    pub(crate) fn config(&self) -> JournalConfig {
        self.config
    }

    /// Counters for benches/tests (group-commit leader syncs folded in).
    pub fn stats(&self) -> JournalStats {
        let mut s = self.stats;
        s.syncs += self.group.state.lock().unwrap().syncs;
        s
    }

    /// The segment directory.
    pub fn seg_dir(&self) -> &Path {
        &self.seg_dir
    }

    /// First sequence number of the active segment.
    pub(crate) fn active_first_seq(&self) -> u64 {
        self.active_first_seq
    }

    /// Crash-point injection: let `n` more appends succeed, then fail
    /// every later one as if the process died mid-write. Wired through
    /// [`FailurePlan`](crate::runs::FailurePlan) for run-level tests.
    pub fn inject_fail_after(&mut self, n: u64) {
        self.fail_after = Some(n);
    }

    /// Arm a [`CrashPoint`] (crash-matrix harness).
    pub(crate) fn inject_crash_point(&mut self, p: CrashPoint) {
        self.crash_point = Some(p);
    }

    /// True if `p` is armed (service-level points check before acting).
    pub(crate) fn crash_armed(&self, p: CrashPoint) -> bool {
        self.crash_point == Some(p)
    }

    /// Fire the armed crash point: poison the journal so every later
    /// append fails, and return the injected error.
    pub(crate) fn trip_crash(&mut self) -> BauplanError {
        self.crash_point = None;
        self.fail_after = Some(0);
        BauplanError::Io(std::io::Error::new(
            std::io::ErrorKind::Other,
            "injected journal crash",
        ))
    }

    /// Debug hook: make the next group-commit leader fsync fail as if the
    /// disk refused the flush — the poison path
    /// ([`BauplanError::Poisoned`]) without a real disk fault. No effect
    /// under non-group policies.
    pub(crate) fn debug_fail_next_group_sync(&mut self) {
        self.group.state.lock().unwrap().fail_next_sync = true;
    }

    /// Simulate power loss under relaxed durability: truncate the active
    /// segment back to its last *synced* length (dropping appended-but-
    /// unsynced records) and poison the handle. The crash matrix uses
    /// this for the enqueue-vs-fsync window of group commit.
    pub(crate) fn debug_lose_unsynced_tail(&mut self) -> Result<()> {
        let synced = match self.config.sync {
            SyncPolicy::GroupCommit => self.group.state.lock().unwrap().synced_bytes,
            _ => self.synced_bytes,
        };
        if let Some(f) = &self.file {
            f.set_len(synced)?;
            f.sync_data()?;
        }
        self.fail_after = Some(0);
        Ok(())
    }

    fn check_fail(&mut self) -> Result<()> {
        if let Some(n) = self.fail_after {
            if n == 0 {
                return Err(BauplanError::Io(std::io::Error::new(
                    std::io::ErrorKind::Other,
                    "injected journal crash",
                )));
            }
            self.fail_after = Some(n - 1);
        }
        Ok(())
    }

    fn file_handle(&self) -> Result<Arc<File>> {
        self.file.clone().ok_or_else(|| {
            BauplanError::Io(std::io::Error::new(
                std::io::ErrorKind::Other,
                "journal poisoned: no active segment",
            ))
        })
    }

    fn sync_data(&self, file: &File) -> Result<()> {
        if self.config.sync_latency_micros > 0 {
            std::thread::sleep(Duration::from_micros(self.config.sync_latency_micros));
        }
        file.sync_data()?;
        Ok(())
    }
}

impl Drop for Journal {
    fn drop(&mut self) {
        // best effort: don't lose batched appends on clean shutdown
        if let Some(f) = &self.file {
            let _ = f.sync_data();
        }
    }
}

/// Fsync a directory so renames/creations/removals inside it are durable
/// (best effort — not all platforms support it).
fn sync_dir(dir: &Path) {
    if let Ok(d) = File::open(dir) {
        let _ = d.sync_all();
    }
}

/// Result of scanning one segment file.
struct SegScan {
    records: Vec<JournalRecord>,
    sealed: bool,
    /// Total file length.
    bytes: u64,
    /// End of the longest valid prefix (active-segment repair point).
    valid_end: u64,
}

/// Scan one segment. `frozen` segments (those with a successor, or a
/// sealed newest segment) must be perfectly valid: any torn/corrupt line
/// fails loudly naming the file. The active segment follows the prefix
/// rule: scanning stops at the first invalid line and reports where.
fn scan_segment(path: &Path, first_seq: u64, frozen: bool) -> Result<SegScan> {
    let mut bytes = Vec::new();
    File::open(path)?.read_to_end(&mut bytes)?;
    let total = bytes.len() as u64;

    let loud = |what: &str| -> BauplanError {
        BauplanError::Parse(format!(
            "frozen journal segment {} corrupt: {what}",
            path.display()
        ))
    };

    let mut records = Vec::new();
    let mut sealed = false;
    let mut offset = 0usize;
    let mut valid_end = 0usize;
    let mut next_expected = first_seq;
    let mut saw_header = false;
    loop {
        if offset >= bytes.len() {
            break;
        }
        let bad: &str;
        let nl = bytes[offset..].iter().position(|&b| b == b'\n');
        match nl {
            Some(rel) => {
                let nl = offset + rel;
                match std::str::from_utf8(&bytes[offset..nl]) {
                    Ok(line) => match parse_seg_line(line) {
                        Ok(SegLine::Header { first_seq: h }) => {
                            if saw_header || offset != 0 || h != first_seq {
                                bad = "misplaced or mismatched header";
                            } else {
                                saw_header = true;
                                offset = nl + 1;
                                valid_end = offset;
                                continue;
                            }
                        }
                        Ok(SegLine::Record(rec)) => {
                            if !saw_header {
                                bad = "record before header";
                            } else if sealed {
                                bad = "record after seal";
                            } else if rec.seq != next_expected {
                                bad = "sequence break";
                            } else {
                                next_expected += 1;
                                records.push(rec);
                                offset = nl + 1;
                                valid_end = offset;
                                continue;
                            }
                        }
                        Ok(SegLine::Seal { last_seq }) => {
                            if !saw_header || sealed || last_seq + 1 != next_expected {
                                bad = "misplaced or mismatched seal";
                            } else {
                                sealed = true;
                                offset = nl + 1;
                                valid_end = offset;
                                continue;
                            }
                        }
                        Err(_) => bad = "unparsable line or crc mismatch",
                    },
                    Err(_) => bad = "torn multi-byte write",
                }
            }
            None => bad = "incomplete final line",
        }
        // invalid from here on
        if frozen {
            return Err(loud(bad));
        }
        break; // active segment: keep the valid prefix
    }
    if frozen {
        if !saw_header {
            return Err(loud("missing header"));
        }
        if !sealed {
            // only reachable for an explicitly-frozen call site (sealed
            // newest segment is detected by the caller via `sealed`)
            return Ok(SegScan { records, sealed, bytes: total, valid_end: valid_end as u64 });
        }
    }
    if !frozen && !saw_header && total > 0 {
        // active segment whose header itself is torn: treat as empty
        return Ok(SegScan { records: Vec::new(), sealed: false, bytes: total, valid_end: 0 });
    }
    Ok(SegScan { records, sealed, bytes: total, valid_end: valid_end as u64 })
}

/// Migrate a legacy single-file `journal.jsonl` into the segment
/// directory: its longest valid prefix becomes the body of a fresh
/// segment (header + records, unsealed → it is the active tail), after
/// which the legacy file is removed. Runs before the directory scan; if
/// a previous migration crashed after writing the segment but before the
/// delete, the leftover legacy file is simply removed (the segment write
/// was synced first).
fn migrate_legacy_journal(dir: &Path, seg_dir: &Path) -> Result<()> {
    let legacy = dir.join(JOURNAL_FILE);
    if !legacy.exists() {
        return Ok(());
    }
    let has_segments = std::fs::read_dir(seg_dir)?
        .filter_map(|e| e.ok())
        .any(|e| parse_segment_name(&e.file_name().to_string_lossy()).is_some());
    if !has_segments {
        let mut bytes = Vec::new();
        File::open(&legacy)?.read_to_end(&mut bytes)?;
        // longest valid prefix, same rule the old scanner used
        let mut records: Vec<JournalRecord> = Vec::new();
        let mut offset = 0usize;
        while offset < bytes.len() {
            let nl = match bytes[offset..].iter().position(|&b| b == b'\n') {
                Some(rel) => offset + rel,
                None => break,
            };
            let line = match std::str::from_utf8(&bytes[offset..nl]) {
                Ok(s) => s,
                Err(_) => break,
            };
            let rec = match JournalRecord::from_line(line) {
                Ok(r) => r,
                Err(_) => break,
            };
            if let Some(prev) = records.last() {
                if rec.seq != prev.seq + 1 {
                    break;
                }
            }
            records.push(rec);
            offset = nl + 1;
        }
        if let Some(first) = records.first() {
            let path = seg_dir.join(segment_name(first.seq));
            let mut out = String::new();
            out.push_str(&header_line(first.seq));
            for rec in &records {
                out.push_str(&rec.to_line());
            }
            let mut f = File::create(&path)?;
            f.write_all(out.as_bytes())?;
            f.sync_data()?;
            sync_dir(seg_dir);
        }
    }
    std::fs::remove_file(&legacy)?;
    sync_dir(dir);
    Ok(())
}

// ---------------------------------------------------------------------------
// Recovery: Catalog::recover / Catalog::open_durable
// ---------------------------------------------------------------------------

impl Catalog {
    /// Reopen (or initialize) a durable lake directory with the default
    /// [`SyncPolicy::GroupCommit`].
    ///
    /// Recovery sequence (spec: `doc/COMMIT_PIPELINE.md` §Recovery):
    /// 1. open the disk-backed object store under `dir/objects`;
    /// 2. load the snapshot chain — newest base + its contiguous deltas
    ///    (falling back to a legacy `catalog.json` + `checkpoint.json`
    ///    pair, else the deterministic init state);
    /// 3. replay every journal record with `seq` above the chain's
    ///    covered floor, *skipping fully-covered segments by file name*,
    ///    repairing a torn tail confined to the active segment;
    /// 4. reattach the journal so subsequent mutations are journaled;
    /// 5. abort every transactional branch still `Open` — its owning run
    ///    process is gone and can never publish (the merge either has a
    ///    journal record, and replayed whole, or never happened: a
    ///    half-merged state cannot be recovered into).
    pub fn recover(dir: impl AsRef<Path>) -> Result<Catalog> {
        Self::open_durable_cfg(dir, JournalConfig::default())
    }

    /// [`Catalog::recover`] with an explicit fsync policy (benches use
    /// [`SyncPolicy::Batch`] to measure group durability).
    pub fn open_durable(dir: impl AsRef<Path>, policy: SyncPolicy) -> Result<Catalog> {
        Self::open_durable_cfg(dir, JournalConfig::with_sync(policy))
    }

    /// [`Catalog::recover`] with full [`JournalConfig`] control (segment
    /// size, compaction threshold, bench sync latency).
    pub fn open_durable_cfg(dir: impl AsRef<Path>, config: JournalConfig) -> Result<Catalog> {
        let dir = dir.as_ref();
        match Self::open_durable_inner(dir, config) {
            Ok(cat) => Ok(cat),
            Err(e) => {
                // a failed recovery leaves no catalog to interrogate, so
                // leave the post-mortem on disk: a one-span flight dump
                // naming the error (best-effort — the recovery error is
                // the thing that must reach the caller)
                let fr = crate::trace::FlightRecorder::new(8);
                let mut fs = fr.begin("catalog.recover");
                fs.fail(e.to_string());
                fs.finish();
                let _ = fr.dump(dir, "recovery failed");
                Err(e)
            }
        }
    }

    fn open_durable_inner(dir: &Path, config: JournalConfig) -> Result<Catalog> {
        std::fs::create_dir_all(dir)?;
        let store = Arc::new(ObjectStore::on_disk(dir.join("objects"))?);

        // newest base + contiguous deltas; legacy checkpoint pair as the
        // fallback for pre-segmentation lakes
        let chain = persist::read_snapshot_chain(dir)?;
        let mut legacy_import = false;
        let (cat, floor, base_seq, deltas_loaded) = match chain {
            Some(chain) => {
                let cat = match &chain.base_state {
                    Some(state) => Catalog::import(state, store)?,
                    // delta-only chain: a fresh lake checkpointed before
                    // its first compaction; deltas chain from the
                    // deterministic init state at seq 0
                    None => Catalog::new(store),
                };
                let n = chain.deltas.len() as u64;
                let mut floor = chain.base_seq;
                for delta in &chain.deltas {
                    cat.apply_snapshot_delta(delta)?;
                    floor = delta.to_seq;
                }
                (cat, floor, chain.base_seq, n)
            }
            None => {
                let ckpt_path = dir.join("catalog.json");
                let cat = if ckpt_path.exists() {
                    let text = std::fs::read_to_string(&ckpt_path)?;
                    legacy_import = true;
                    Catalog::import(&Json::parse(&text)?, store)?
                } else {
                    Catalog::new(store)
                };
                (cat, persist::read_checkpoint_seq(dir)?, 0, 0)
            }
        };

        let (journal, scan) = Journal::open(dir, config, floor)?;
        for rec in &scan.records {
            cat.apply_journal_record(rec)?;
        }
        let mut rstats = scan.stats;
        rstats.base_seq = base_seq;
        rstats.deltas_loaded = deltas_loaded;
        let replayed = scan.records.len() as u64;
        cat.attach_durability(dir.to_path_buf(), journal, floor, deltas_loaded, rstats);
        {
            let mut fs = cat.flight().begin("catalog.recover");
            fs.attr_u64("replayed", replayed);
            fs.attr_u64("deltas_loaded", deltas_loaded);
            fs.attr_u64("base_seq", base_seq);
            fs.finish();
        }

        // recovery policy: orphaned in-flight runs abort (journaled, so the
        // next recovery replays the same answer)
        for b in cat.list_branches() {
            if b.transactional && b.state == BranchState::Open {
                cat.set_branch_state(&b.name, BranchState::Aborted)?;
            }
        }
        cat.journal_sync()?;
        if legacy_import {
            // migrate the pre-segmentation checkpoint forward: a base
            // snapshot makes future delta checkpoints chain correctly
            // (deltas cannot chain onto a legacy catalog.json), and
            // compaction retires the legacy pair it supersedes
            cat.compact()?;
        }
        Ok(cat)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn commit_fixture() -> Commit {
        let mut tables = std::collections::BTreeMap::new();
        tables.insert("t".to_string(), "snap1".to_string());
        Commit::new_at(vec!["p0".into()], tables, "u", "msg", Some("r1".into()), 42)
    }

    fn tmp(tag: &str) -> PathBuf {
        let dir = std::env::temp_dir().join(format!("bpl_{tag}_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        dir
    }

    #[test]
    fn record_line_roundtrip() {
        let rec = JournalRecord {
            seq: 7,
            op: JournalOp::Commit {
                branch: "main".into(),
                commit: commit_fixture(),
                snapshot: Some(Snapshot::new(vec!["k1".into()], "S", "fp", 3, "r1")),
            },
        };
        let line = rec.to_line();
        assert!(line.ends_with('\n'));
        let back = JournalRecord::from_line(line.trim_end()).unwrap();
        assert_eq!(back, rec);
    }

    #[test]
    fn crc_detects_tampering() {
        let rec = JournalRecord {
            seq: 1,
            op: JournalOp::Tag { name: "v1".into(), target: "c0".into() },
        };
        let line = rec.to_line();
        let tampered = line.replace("v1", "v2");
        assert!(JournalRecord::from_line(tampered.trim_end()).is_err());
    }

    #[test]
    fn all_op_kinds_roundtrip() {
        let ops = vec![
            JournalOp::Replay {
                branch: "dev".into(),
                commits: vec![commit_fixture()],
            },
            JournalOp::BranchCreate {
                info: BranchInfo::transactional("txn/r1", "c0".into(), "r1"),
            },
            JournalOp::SetBranchState { name: "txn/r1".into(), state: BranchState::Aborted },
            JournalOp::BranchDelete { name: "tmp".into() },
            JournalOp::Tag { name: "v1".into(), target: "c9".into() },
            JournalOp::Head { branch: "main".into(), commit: "c3".into() },
            JournalOp::RegisterSnapshot {
                snapshot: Snapshot::new(vec!["o1".into(), "o2".into()], "S", "fp", 9, "r"),
            },
            JournalOp::Gc { pins: vec![] },
            JournalOp::Gc { pins: vec!["snap_a".into(), "snap_b".into()] },
            JournalOp::RunRecord {
                run_id: "run_7".into(),
                record: crate::util::json::Json::obj(vec![
                    ("pipeline", crate::util::json::Json::str("paper_dag")),
                    ("status", crate::util::json::Json::str("success")),
                ]),
            },
            JournalOp::RunTrace {
                run_id: "run_7".into(),
                trace: crate::util::json::Json::obj(vec![
                    ("trace_id", crate::util::json::Json::str("trace_1")),
                    ("spans", crate::util::json::Json::Arr(vec![])),
                ]),
            },
        ];
        for (i, op) in ops.into_iter().enumerate() {
            let rec = JournalRecord { seq: i as u64 + 1, op };
            let back = JournalRecord::from_line(rec.to_line().trim_end()).unwrap();
            assert_eq!(back, rec);
        }
    }

    #[test]
    fn header_and_seal_lines_roundtrip() {
        match parse_seg_line(header_line(42).trim_end()).unwrap() {
            SegLine::Header { first_seq } => assert_eq!(first_seq, 42),
            _ => panic!("not a header"),
        }
        match parse_seg_line(seal_line(99).trim_end()).unwrap() {
            SegLine::Seal { last_seq } => assert_eq!(last_seq, 99),
            _ => panic!("not a seal"),
        }
        // tampering breaks the crc
        let tampered = header_line(42).replace("42", "43");
        assert!(parse_seg_line(tampered.trim_end()).is_err());
    }

    #[test]
    fn segment_names_roundtrip_and_sort() {
        assert_eq!(parse_segment_name(&segment_name(7)), Some(7));
        assert_eq!(parse_segment_name(&segment_name(u64::from(u32::MAX))), Some(4294967295));
        assert_eq!(parse_segment_name("seg-x.jsonl"), None);
        assert_eq!(parse_segment_name("journal.jsonl"), None);
        // zero-padding makes lexicographic order numeric order
        assert!(segment_name(9) < segment_name(10));
    }

    #[test]
    fn journal_scan_stops_at_bad_sequence_in_active_tail() {
        let dir = tmp("jseq");
        let seg_dir = dir.join(JOURNAL_DIR);
        std::fs::create_dir_all(&seg_dir).unwrap();
        let r1 = JournalRecord { seq: 1, op: JournalOp::Gc { pins: vec![] } };
        let r3 = JournalRecord { seq: 3, op: JournalOp::Gc { pins: vec![] } }; // gap!
        std::fs::write(
            seg_dir.join(segment_name(1)),
            format!("{}{}{}", header_line(1), r1.to_line(), r3.to_line()),
        )
        .unwrap();
        let (j, scan) = Journal::open(&dir, JournalConfig::default(), 0).unwrap();
        assert_eq!(scan.records.len(), 1);
        assert_eq!(j.last_seq(), 1);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn batch_policy_syncs_less_often() {
        let dir = tmp("jbatch");
        let (mut j, _) = Journal::open(
            &dir,
            JournalConfig::with_sync(SyncPolicy::Batch(8)),
            0,
        )
        .unwrap();
        let open_syncs = j.stats().syncs;
        for _ in 0..16 {
            let (_, t) = j.append(&JournalOp::Gc { pins: vec![] }).unwrap();
            t.wait().unwrap();
        }
        assert_eq!(j.stats().appends, 16);
        assert_eq!(j.stats().syncs - open_syncs, 2);
        j.sync().unwrap();
        assert_eq!(j.stats().syncs - open_syncs, 3);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn rotation_seals_and_new_segment_continues_sequence() {
        let dir = tmp("jrot");
        let mut cfg = JournalConfig::with_sync(SyncPolicy::EveryAppend);
        cfg.segment_bytes = 256; // tiny: force rotations
        let (mut j, _) = Journal::open(&dir, cfg, 0).unwrap();
        for _ in 0..20 {
            let (_, t) = j.append(&JournalOp::Gc { pins: vec![] }).unwrap();
            t.wait().unwrap();
        }
        assert!(j.stats().rotations > 0, "tiny segments must rotate");
        drop(j);
        // reopen: all 20 records come back, across segments
        let (j2, scan) = Journal::open(&dir, cfg, 0).unwrap();
        assert_eq!(scan.records.len(), 20);
        assert_eq!(scan.records.last().unwrap().seq, 20);
        assert_eq!(j2.last_seq(), 20);
        assert!(scan.stats.segments_scanned >= 2);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn covered_segments_are_skipped_by_name() {
        let dir = tmp("jskip");
        let mut cfg = JournalConfig::with_sync(SyncPolicy::EveryAppend);
        cfg.segment_bytes = 256;
        let (mut j, _) = Journal::open(&dir, cfg, 0).unwrap();
        for _ in 0..30 {
            let (_, t) = j.append(&JournalOp::Gc { pins: vec![] }).unwrap();
            t.wait().unwrap();
        }
        let rotations = j.stats().rotations;
        assert!(rotations >= 2);
        drop(j);
        // a floor covering everything but the active segment skips every
        // frozen segment by name
        let active_first = {
            let (j2, _) = Journal::open(&dir, cfg, 0).unwrap();
            j2.active_first_seq()
        };
        let floor = active_first - 1;
        let (_, scan) = Journal::open(&dir, cfg, floor).unwrap();
        assert_eq!(scan.stats.segments_skipped, rotations);
        assert!(scan.records.iter().all(|r| r.seq > floor));
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn frozen_segment_corruption_is_loud_in_scan() {
        let dir = tmp("jfrozen");
        let mut cfg = JournalConfig::with_sync(SyncPolicy::EveryAppend);
        cfg.segment_bytes = 256;
        let (mut j, _) = Journal::open(&dir, cfg, 0).unwrap();
        for _ in 0..20 {
            let (_, t) = j.append(&JournalOp::Gc { pins: vec![] }).unwrap();
            t.wait().unwrap();
        }
        assert!(j.stats().rotations > 0);
        let seg_dir = j.seg_dir().to_path_buf();
        drop(j);
        // corrupt a byte in the middle of the FIRST (frozen) segment
        let mut names: Vec<_> = std::fs::read_dir(&seg_dir)
            .unwrap()
            .filter_map(|e| e.ok())
            .map(|e| e.path())
            .filter(|p| parse_segment_name(&p.file_name().unwrap().to_string_lossy()).is_some())
            .collect();
        names.sort();
        let frozen = &names[0];
        let mut bytes = std::fs::read(frozen).unwrap();
        let mid = bytes.len() / 2;
        bytes[mid] = bytes[mid].wrapping_add(1);
        std::fs::write(frozen, &bytes).unwrap();
        let err = Journal::open(&dir, cfg, 0).unwrap_err();
        let msg = format!("{err}");
        assert!(
            msg.contains(&frozen.file_name().unwrap().to_string_lossy().to_string()),
            "error must name the corrupt segment: {msg}"
        );
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn torn_active_header_is_recreated_and_acknowledged_appends_survive() {
        // crash during rotation's header write: seg-1 is sealed and a
        // successor exists but holds only half a header line
        let dir = tmp("jtornhdr");
        let seg_dir = dir.join(JOURNAL_DIR);
        std::fs::create_dir_all(&seg_dir).unwrap();
        let r1 = JournalRecord { seq: 1, op: JournalOp::Gc { pins: vec![] } };
        let r2 = JournalRecord { seq: 2, op: JournalOp::Gc { pins: vec![] } };
        std::fs::write(
            seg_dir.join(segment_name(1)),
            format!("{}{}{}{}", header_line(1), r1.to_line(), r2.to_line(), seal_line(2)),
        )
        .unwrap();
        let torn = header_line(3);
        std::fs::write(seg_dir.join(segment_name(3)), &torn.as_bytes()[..torn.len() / 2])
            .unwrap();

        let cfg = JournalConfig::with_sync(SyncPolicy::EveryAppend);
        let (mut j, scan) = Journal::open(&dir, cfg, 0).unwrap();
        assert_eq!(scan.records.len(), 2, "frozen records replay");
        // an acknowledged append lands in the recreated active tail
        let (seq, t) = j.append(&JournalOp::Gc { pins: vec![] }).unwrap();
        t.wait().unwrap();
        assert_eq!(seq, 3);
        drop(j);
        // the next recovery must not discard it as "record before header"
        let (_, scan2) = Journal::open(&dir, cfg, 0).unwrap();
        assert_eq!(scan2.records.len(), 3, "acknowledged append must survive");
        assert_eq!(scan2.records.last().unwrap().seq, 3);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn empty_active_segment_file_is_recreated_with_a_header() {
        // crash between creating the active segment file and writing its
        // header: a zero-byte seg file
        let dir = tmp("jemptyseg");
        let seg_dir = dir.join(JOURNAL_DIR);
        std::fs::create_dir_all(&seg_dir).unwrap();
        std::fs::write(seg_dir.join(segment_name(1)), b"").unwrap();
        let cfg = JournalConfig::with_sync(SyncPolicy::EveryAppend);
        let (mut j, scan) = Journal::open(&dir, cfg, 0).unwrap();
        assert!(scan.records.is_empty());
        let (_, t) = j.append(&JournalOp::Gc { pins: vec![] }).unwrap();
        t.wait().unwrap();
        drop(j);
        let (_, scan2) = Journal::open(&dir, cfg, 0).unwrap();
        assert_eq!(scan2.records.len(), 1);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn group_commit_ticket_waits_for_leader_sync() {
        let dir = tmp("jgroup");
        let cfg = JournalConfig::with_sync(SyncPolicy::GroupCommit);
        let (mut j, _) = Journal::open(&dir, cfg, 0).unwrap();
        let (seq, t) = j.append(&JournalOp::Gc { pins: vec![] }).unwrap();
        assert_eq!(seq, 1);
        // the waiter becomes the leader and syncs itself
        t.wait().unwrap();
        assert_eq!(j.stats().syncs, 1);
        // a second append + wait syncs again
        let (_, t2) = j.append(&JournalOp::Gc { pins: vec![] }).unwrap();
        t2.wait().unwrap();
        assert_eq!(j.stats().syncs, 2);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn leader_finishing_after_rotation_skips_stale_byte_merge() {
        let dir = tmp("jepoch");
        let cfg = JournalConfig::with_sync(SyncPolicy::GroupCommit);
        let (mut j, _) = Journal::open(&dir, cfg, 0).unwrap();
        let mut last = None;
        for _ in 0..5 {
            let (_, t) = j.append(&JournalOp::Gc { pins: vec![] }).unwrap();
            last = Some(t);
        }
        // slow down only the leader's fsync so the rotation below lands
        // inside its capture-to-merge window
        j.group.state.lock().unwrap().sync_latency_micros = 300_000;
        let t = last.unwrap();
        let leader = std::thread::spawn(move || t.wait());
        std::thread::sleep(Duration::from_millis(50));
        j.rotate_if_nonempty().unwrap();
        leader.join().unwrap().unwrap();
        let st = j.group.state.lock().unwrap();
        assert_eq!(st.epoch, j.active_first_seq);
        assert!(
            st.synced_bytes <= st.appended_bytes,
            "stale leader merge inflated synced_bytes ({} > {})",
            st.synced_bytes,
            st.appended_bytes
        );
        drop(st);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn legacy_single_file_journal_migrates_into_a_segment() {
        let dir = tmp("jlegacy");
        let r1 = JournalRecord { seq: 1, op: JournalOp::Gc { pins: vec![] } };
        let r2 = JournalRecord { seq: 2, op: JournalOp::Tag { name: "v1".into(), target: "c0".into() } };
        std::fs::write(dir.join(JOURNAL_FILE), format!("{}{}", r1.to_line(), r2.to_line()))
            .unwrap();
        let (j, scan) = Journal::open(&dir, JournalConfig::default(), 0).unwrap();
        assert_eq!(scan.records.len(), 2);
        assert_eq!(j.last_seq(), 2);
        assert!(!dir.join(JOURNAL_FILE).exists(), "legacy file must be consumed");
        drop(j);
        // second open replays the same records from the migrated segment
        let (_, scan2) = Journal::open(&dir, JournalConfig::default(), 0).unwrap();
        assert_eq!(scan2.records, scan.records);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn lose_unsynced_tail_drops_unacknowledged_records() {
        let dir = tmp("jlose");
        let cfg = JournalConfig::with_sync(SyncPolicy::GroupCommit);
        let (mut j, _) = Journal::open(&dir, cfg, 0).unwrap();
        let (_, t) = j.append(&JournalOp::Gc { pins: vec![] }).unwrap();
        t.wait().unwrap(); // seq 1 durable
        let (_, _t2) = j.append(&JournalOp::Gc { pins: vec![] }).unwrap();
        // seq 2 enqueued but never fsynced: power loss
        j.debug_lose_unsynced_tail().unwrap();
        drop(j);
        let (_, scan) = Journal::open(&dir, cfg, 0).unwrap();
        assert_eq!(scan.records.len(), 1, "unsynced record must be gone");
        assert_eq!(scan.records[0].seq, 1);
        let _ = std::fs::remove_dir_all(&dir);
    }
}
