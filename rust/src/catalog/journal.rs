//! The durable append-only commit journal (write-ahead log) and the
//! recovery path.
//!
//! Every catalog mutation appends one canonical-JSON record here *before*
//! its ref update becomes visible to readers (the write-ahead discipline;
//! see `doc/COMMIT_PIPELINE.md` for the full spec). Recovery is
//! `load(checkpoint) + replay(journal tail)`:
//!
//! - [`Catalog::recover`] reopens a durable lake directory: it imports the
//!   last checkpoint (if any), replays every journal record with a
//!   sequence number past the checkpoint, repairs a torn tail, and
//!   reattaches the journal so subsequent writes are durable again.
//! - [`Catalog::checkpoint`](crate::catalog::Catalog::checkpoint) bounds
//!   replay work: it writes the canonical export atomically and truncates
//!   the journal.
//!
//! ## File format
//!
//! `journal.jsonl` is a sequence of `\n`-terminated lines. Each line is a
//! canonical-JSON object `{"crc":H,"data":D,"op":O,"seq":N}` where `H` is
//! the content hash of the canonical serialization of
//! `{"data":D,"op":O,"seq":N}`. Sequence numbers are strictly consecutive
//! within a file. Records are *physical*: they carry the full commit
//! (including its timestamp) and snapshot payloads, so replay rebuilds
//! byte-identical state without re-running any logic whose output depends
//! on the clock or on merge heuristics.
//!
//! ## Torn tails
//!
//! A crash can leave a partial last line (and, under batched fsync, lose
//! a suffix of records). Recovery applies the longest valid prefix: the
//! scan stops at the first line that is incomplete, unparsable, fails its
//! crc, or breaks the sequence, and truncates the file there. This is the
//! standard WAL prefix rule — covered by
//! `torn_tail_is_discarded_and_journal_reusable` in
//! `tests/integration_journal.rs`.

use std::fs::{File, OpenOptions};
use std::io::{Read, Seek, SeekFrom, Write};
use std::path::{Path, PathBuf};
use std::sync::Arc;

use crate::catalog::commit::Commit;
use crate::catalog::persist;
use crate::catalog::refs::{BranchInfo, BranchState};
use crate::catalog::snapshot::Snapshot;
use crate::catalog::Catalog;
use crate::error::{BauplanError, Result};
use crate::storage::ObjectStore;
use crate::util::id::content_hash;
use crate::util::json::Json;

/// File name of the journal inside a durable lake directory.
pub const JOURNAL_FILE: &str = "journal.jsonl";

/// When the journal calls `fsync` relative to appends.
///
/// The append itself always reaches the OS before the mutation becomes
/// visible; the policy only controls when the OS buffer is forced to
/// stable storage.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SyncPolicy {
    /// `fsync` after every append — an acknowledged write is crash-durable.
    EveryAppend,
    /// `fsync` once per `n` appends (group durability). A crash may lose
    /// the unsynced suffix, but recovery still lands on a consistent
    /// prefix state. [`Catalog::journal_sync`] forces a flush.
    Batch(u64),
}

impl Default for SyncPolicy {
    fn default() -> Self {
        SyncPolicy::EveryAppend
    }
}

/// Counters exposed for benches and tests.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct JournalStats {
    /// Records appended through this handle.
    pub appends: u64,
    /// `fsync` calls issued.
    pub syncs: u64,
    /// Bytes written (journal lines only).
    pub bytes_written: u64,
    /// Highest sequence number ever assigned (0 = none).
    pub last_seq: u64,
}

/// One journaled mutation. Records are physical: they carry the exact
/// commits/snapshots/branch metadata the mutation produced, so replay is
/// deterministic and byte-exact.
#[derive(Debug, Clone, PartialEq)]
pub enum JournalOp {
    /// A new commit advanced `branch` (covers `commit_table`,
    /// `commit_table_cas`, `delete_table`, and three-way merge commits).
    /// `snapshot` is the snapshot the commit introduced, if any.
    Commit {
        /// Branch whose head advanced.
        branch: String,
        /// The full new commit (timestamp included).
        commit: Commit,
        /// Snapshot registered together with the commit, if any.
        snapshot: Option<Snapshot>,
    },
    /// A rebase/cherry-pick applied a batch of commits atomically
    /// (`apply_deltas`): all commits insert and the head moves to the
    /// last one — one record, so the batch is all-or-nothing on disk.
    Replay {
        /// Branch whose head advanced.
        branch: String,
        /// Commits in application order; head lands on the last.
        commits: Vec<Commit>,
    },
    /// A branch was created (normal or transactional).
    BranchCreate {
        /// The full branch metadata at creation.
        info: BranchInfo,
    },
    /// A transactional branch changed lifecycle state.
    SetBranchState {
        /// Branch name.
        name: String,
        /// New lifecycle state.
        state: BranchState,
    },
    /// A branch was deleted.
    BranchDelete {
        /// Branch name.
        name: String,
    },
    /// A tag was created.
    Tag {
        /// Tag name.
        name: String,
        /// Commit id the tag pins.
        target: String,
    },
    /// A branch head moved to an existing commit without a new commit
    /// (fast-forward merge, rebase epilogue).
    Head {
        /// Branch whose head moved.
        branch: String,
        /// Commit id it now points at.
        commit: String,
    },
    /// A snapshot was registered ahead of its commit (`register_snapshot`).
    RegisterSnapshot {
        /// The full snapshot.
        snapshot: Snapshot,
    },
    /// Garbage collection ran. The record carries the pinned-snapshot
    /// roots the sweep used (pins are not otherwise journaled), so
    /// replay re-runs the identical deterministic mark-and-sweep and
    /// recovered state matches the post-gc export.
    Gc {
        /// Pinned-snapshot GC roots at sweep time, sorted.
        pins: Vec<String>,
    },
    /// A run reached a terminal state. The record is opaque JSON owned
    /// by the run engine (`runs::RunState` codec) — the catalog journals
    /// and checkpoints it so `get_run` survives process restarts.
    RunRecord {
        /// The run id the record describes.
        run_id: String,
        /// The run engine's serialized terminal state.
        record: crate::util::json::Json,
    },
}

/// A sequenced journal record.
#[derive(Debug, Clone, PartialEq)]
pub struct JournalRecord {
    /// Strictly increasing sequence number (1-based).
    pub seq: u64,
    /// The mutation.
    pub op: JournalOp,
}

impl JournalRecord {
    fn op_name(&self) -> &'static str {
        match &self.op {
            JournalOp::Commit { .. } => "commit",
            JournalOp::Replay { .. } => "replay",
            JournalOp::BranchCreate { .. } => "branch_create",
            JournalOp::SetBranchState { .. } => "branch_state",
            JournalOp::BranchDelete { .. } => "branch_delete",
            JournalOp::Tag { .. } => "tag",
            JournalOp::Head { .. } => "head",
            JournalOp::RegisterSnapshot { .. } => "snapshot",
            JournalOp::Gc { .. } => "gc",
            JournalOp::RunRecord { .. } => "run_record",
        }
    }

    fn data_json(&self) -> Json {
        match &self.op {
            JournalOp::Commit { branch, commit, snapshot } => Json::obj(vec![
                ("branch", Json::str(branch)),
                ("commit_id", Json::str(&commit.id)),
                ("commit", persist::commit_to_json(commit)),
                (
                    "snapshot_id",
                    snapshot.as_ref().map(|s| Json::str(&s.id)).unwrap_or(Json::Null),
                ),
                (
                    "snapshot",
                    snapshot.as_ref().map(persist::snapshot_to_json).unwrap_or(Json::Null),
                ),
            ]),
            JournalOp::Replay { branch, commits } => Json::obj(vec![
                ("branch", Json::str(branch)),
                (
                    "commits",
                    Json::Arr(
                        commits
                            .iter()
                            .map(|c| {
                                Json::obj(vec![
                                    ("commit_id", Json::str(&c.id)),
                                    ("commit", persist::commit_to_json(c)),
                                ])
                            })
                            .collect(),
                    ),
                ),
            ]),
            JournalOp::BranchCreate { info } => Json::obj(vec![
                ("name", Json::str(&info.name)),
                ("branch", persist::branch_to_json(info)),
            ]),
            JournalOp::SetBranchState { name, state } => Json::obj(vec![
                ("name", Json::str(name)),
                ("state", Json::str(persist::branch_state_str(*state))),
            ]),
            JournalOp::BranchDelete { name } => {
                Json::obj(vec![("name", Json::str(name))])
            }
            JournalOp::Tag { name, target } => Json::obj(vec![
                ("name", Json::str(name)),
                ("target", Json::str(target)),
            ]),
            JournalOp::Head { branch, commit } => Json::obj(vec![
                ("branch", Json::str(branch)),
                ("commit", Json::str(commit)),
            ]),
            JournalOp::RegisterSnapshot { snapshot } => Json::obj(vec![
                ("snapshot_id", Json::str(&snapshot.id)),
                ("snapshot", persist::snapshot_to_json(snapshot)),
            ]),
            JournalOp::Gc { pins } => Json::obj(vec![(
                "pins",
                Json::Arr(pins.iter().map(Json::str).collect()),
            )]),
            JournalOp::RunRecord { run_id, record } => Json::obj(vec![
                ("run_id", Json::str(run_id)),
                ("record", record.clone()),
            ]),
        }
    }

    /// Serialize to one canonical journal line (`\n`-terminated).
    pub fn to_line(&self) -> String {
        let inner = Json::obj(vec![
            ("data", self.data_json()),
            ("op", Json::str(self.op_name())),
            ("seq", Json::num(self.seq as f64)),
        ]);
        let body = inner.to_string();
        let crc = content_hash(body.as_bytes());
        // canonical key order puts "crc" first, so splice it into the
        // already-serialized body rather than building the tree twice —
        // this runs under the catalog write lock on every mutation
        format!("{{\"crc\":\"{crc}\",{}\n", &body[1..])
    }

    /// Parse and integrity-check one journal line (without the trailing
    /// newline). Fails on malformed JSON, a crc mismatch, or an unknown op.
    pub fn from_line(line: &str) -> Result<JournalRecord> {
        let v = Json::parse(line)?;
        let crc = v
            .get("crc")
            .as_str()
            .ok_or_else(|| BauplanError::Parse("journal record: missing crc".into()))?
            .to_string();
        let seq = v
            .get("seq")
            .as_f64()
            .ok_or_else(|| BauplanError::Parse("journal record: missing seq".into()))?
            as u64;
        let op_name = v
            .get("op")
            .as_str()
            .ok_or_else(|| BauplanError::Parse("journal record: missing op".into()))?
            .to_string();
        let data = v.get("data").clone();
        // verify the crc over the canonical {data, op, seq} serialization
        let inner = Json::obj(vec![
            ("data", data.clone()),
            ("op", Json::str(&op_name)),
            ("seq", Json::num(seq as f64)),
        ]);
        if content_hash(inner.to_string().as_bytes()) != crc {
            return Err(BauplanError::Parse(format!(
                "journal record seq {seq}: crc mismatch"
            )));
        }
        let str_field = |j: &Json, k: &str| -> Result<String> {
            j.get(k)
                .as_str()
                .map(String::from)
                .ok_or_else(|| BauplanError::Parse(format!("journal record: missing {k}")))
        };
        let op = match op_name.as_str() {
            "commit" => {
                let branch = str_field(&data, "branch")?;
                let id = str_field(&data, "commit_id")?;
                let commit = persist::commit_from_json(&id, data.get("commit"));
                let snapshot = match data.get("snapshot_id").as_str() {
                    Some(sid) => {
                        Some(persist::snapshot_from_json(sid, data.get("snapshot")))
                    }
                    None => None,
                };
                JournalOp::Commit { branch, commit, snapshot }
            }
            "replay" => {
                let branch = str_field(&data, "branch")?;
                let mut commits = Vec::new();
                for cj in data.get("commits").as_arr().unwrap_or(&[]) {
                    let id = str_field(cj, "commit_id")?;
                    commits.push(persist::commit_from_json(&id, cj.get("commit")));
                }
                if commits.is_empty() {
                    return Err(BauplanError::Parse(
                        "journal record: replay with no commits".into(),
                    ));
                }
                JournalOp::Replay { branch, commits }
            }
            "branch_create" => {
                let name = str_field(&data, "name")?;
                let info = persist::branch_from_json(&name, data.get("branch"))?;
                JournalOp::BranchCreate { info }
            }
            "branch_state" => JournalOp::SetBranchState {
                name: str_field(&data, "name")?,
                state: persist::parse_branch_state(&str_field(&data, "state")?)?,
            },
            "branch_delete" => JournalOp::BranchDelete { name: str_field(&data, "name")? },
            "tag" => JournalOp::Tag {
                name: str_field(&data, "name")?,
                target: str_field(&data, "target")?,
            },
            "head" => JournalOp::Head {
                branch: str_field(&data, "branch")?,
                commit: str_field(&data, "commit")?,
            },
            "snapshot" => {
                let sid = str_field(&data, "snapshot_id")?;
                JournalOp::RegisterSnapshot {
                    snapshot: persist::snapshot_from_json(&sid, data.get("snapshot")),
                }
            }
            // lenient on `pins`: pre-cache records carried no data
            "gc" => JournalOp::Gc {
                pins: data
                    .get("pins")
                    .as_arr()
                    .unwrap_or(&[])
                    .iter()
                    .filter_map(|p| p.as_str().map(String::from))
                    .collect(),
            },
            "run_record" => JournalOp::RunRecord {
                run_id: str_field(&data, "run_id")?,
                record: data.get("record").clone(),
            },
            other => {
                return Err(BauplanError::Parse(format!(
                    "journal record: unknown op '{other}'"
                )))
            }
        };
        Ok(JournalRecord { seq, op })
    }
}

/// The append-only journal file handle.
///
/// Owned by the catalog's durability slot and driven only while the
/// catalog's write lock is held, so appends are totally ordered and
/// sequence numbers never race.
pub struct Journal {
    path: PathBuf,
    file: File,
    next_seq: u64,
    policy: SyncPolicy,
    unsynced: u64,
    stats: JournalStats,
    /// Fail the (n+1)-th append from now — crash-point injection for the
    /// write-ahead-discipline tests.
    fail_after: Option<u64>,
}

impl Journal {
    /// Open (or create) the journal at `path`, scan it, repair a torn
    /// tail, and return the handle plus every valid record in order.
    ///
    /// `floor_seq` is the checkpoint's last covered sequence number; the
    /// handle continues numbering above both it and anything found in the
    /// file.
    pub fn open(
        path: impl Into<PathBuf>,
        policy: SyncPolicy,
        floor_seq: u64,
    ) -> Result<(Journal, Vec<JournalRecord>)> {
        let path = path.into();
        let mut file = OpenOptions::new()
            .read(true)
            .write(true)
            .create(true)
            .open(&path)?;
        let mut bytes = Vec::new();
        file.read_to_end(&mut bytes)?;

        let mut records: Vec<JournalRecord> = Vec::new();
        let mut offset = 0usize; // start of the current line
        let mut valid_end = 0usize; // end of the last valid line
        while offset < bytes.len() {
            let nl = match bytes[offset..].iter().position(|&b| b == b'\n') {
                Some(rel) => offset + rel,
                None => break, // incomplete final line: torn tail
            };
            let line = match std::str::from_utf8(&bytes[offset..nl]) {
                Ok(s) => s,
                Err(_) => break, // torn multi-byte write
            };
            let rec = match JournalRecord::from_line(line) {
                Ok(r) => r,
                Err(_) => break, // bad json / crc / op: stop at the prefix
            };
            // sequence must be consecutive (first record may start anywhere
            // above 0 — the file may begin right after a checkpoint)
            if let Some(prev) = records.last() {
                if rec.seq != prev.seq + 1 {
                    break;
                }
            }
            records.push(rec);
            offset = nl + 1;
            valid_end = offset;
        }
        if valid_end < bytes.len() {
            // discard the torn/invalid suffix so future appends extend a
            // clean prefix
            file.set_len(valid_end as u64)?;
            file.sync_data()?;
        }
        file.seek(SeekFrom::End(0))?;

        let max_seq = records.last().map(|r| r.seq).unwrap_or(0).max(floor_seq);
        let stats = JournalStats { last_seq: max_seq, ..JournalStats::default() };
        Ok((
            Journal {
                path,
                file,
                next_seq: max_seq + 1,
                policy,
                unsynced: 0,
                stats,
                fail_after: None,
            },
            records,
        ))
    }

    /// Append one record; returns its sequence number. The record is
    /// written (and, per [`SyncPolicy`], fsynced) before this returns —
    /// the caller applies the in-memory mutation only afterwards.
    pub fn append(&mut self, op: JournalOp) -> Result<u64> {
        if let Some(n) = self.fail_after {
            if n == 0 {
                return Err(BauplanError::Io(std::io::Error::new(
                    std::io::ErrorKind::Other,
                    "injected journal crash",
                )));
            }
            self.fail_after = Some(n - 1);
        }
        let seq = self.next_seq;
        let line = JournalRecord { seq, op }.to_line();
        self.file.write_all(line.as_bytes())?;
        self.next_seq += 1;
        self.stats.appends += 1;
        self.stats.bytes_written += line.len() as u64;
        self.stats.last_seq = seq;
        match self.policy {
            SyncPolicy::EveryAppend => {
                self.file.sync_data()?;
                self.stats.syncs += 1;
            }
            SyncPolicy::Batch(n) => {
                self.unsynced += 1;
                if self.unsynced >= n.max(1) {
                    self.file.sync_data()?;
                    self.stats.syncs += 1;
                    self.unsynced = 0;
                }
            }
        }
        Ok(seq)
    }

    /// Force any batched appends to stable storage.
    pub fn sync(&mut self) -> Result<()> {
        if self.unsynced > 0 || matches!(self.policy, SyncPolicy::Batch(_)) {
            self.file.sync_data()?;
            self.stats.syncs += 1;
            self.unsynced = 0;
        }
        Ok(())
    }

    /// Empty the file after a checkpoint captured every record. Sequence
    /// numbering continues — the checkpoint metadata records the floor.
    pub fn truncate(&mut self) -> Result<()> {
        self.file.set_len(0)?;
        self.file.seek(SeekFrom::Start(0))?;
        self.file.sync_data()?;
        self.unsynced = 0;
        Ok(())
    }

    /// Highest sequence number assigned so far (0 = none).
    pub fn last_seq(&self) -> u64 {
        self.next_seq - 1
    }

    /// Counters for benches/tests.
    pub fn stats(&self) -> JournalStats {
        self.stats
    }

    /// Path of the journal file.
    pub fn path(&self) -> &Path {
        &self.path
    }

    /// Crash-point injection: let `n` more appends succeed, then fail
    /// every later one as if the process died mid-write. Wired through
    /// [`FailurePlan`](crate::runs::FailurePlan) for run-level tests.
    pub fn inject_fail_after(&mut self, n: u64) {
        self.fail_after = Some(n);
    }
}

impl Drop for Journal {
    fn drop(&mut self) {
        // best effort: don't lose batched appends on clean shutdown
        let _ = self.file.sync_data();
    }
}

// ---------------------------------------------------------------------------
// Recovery: Catalog::recover / Catalog::open_durable
// ---------------------------------------------------------------------------

impl Catalog {
    /// Reopen (or initialize) a durable lake directory with the default
    /// [`SyncPolicy::EveryAppend`].
    ///
    /// Recovery sequence (spec: `doc/COMMIT_PIPELINE.md` §Recovery):
    /// 1. open the disk-backed object store under `dir/objects`;
    /// 2. import the checkpoint `catalog.json` if present (else start at
    ///    the deterministic init state);
    /// 3. replay every journal record with `seq` above the checkpoint's
    ///    covered floor, repairing a torn tail;
    /// 4. reattach the journal so subsequent mutations are journaled;
    /// 5. abort every transactional branch still `Open` — its owning run
    ///    process is gone and can never publish (the merge either has a
    ///    journal record, and replayed whole, or never happened: a
    ///    half-merged state cannot be recovered into).
    pub fn recover(dir: impl AsRef<Path>) -> Result<Catalog> {
        Self::open_durable(dir, SyncPolicy::EveryAppend)
    }

    /// [`Catalog::recover`] with an explicit fsync policy (benches use
    /// [`SyncPolicy::Batch`] to measure group durability).
    pub fn open_durable(dir: impl AsRef<Path>, policy: SyncPolicy) -> Result<Catalog> {
        let dir = dir.as_ref();
        std::fs::create_dir_all(dir)?;
        let store = Arc::new(ObjectStore::on_disk(dir.join("objects"))?);

        let ckpt_path = dir.join("catalog.json");
        let cat = if ckpt_path.exists() {
            let text = std::fs::read_to_string(&ckpt_path)?;
            Catalog::import(&Json::parse(&text)?, store)?
        } else {
            Catalog::new(store)
        };

        let floor = persist::read_checkpoint_seq(dir)?;
        let (journal, records) = Journal::open(dir.join(JOURNAL_FILE), policy, floor)?;
        for rec in &records {
            if rec.seq <= floor {
                continue; // already captured by the checkpoint
            }
            cat.apply_journal_record(rec)?;
        }
        cat.attach_durability(dir.to_path_buf(), journal);

        // recovery policy: orphaned in-flight runs abort (journaled, so the
        // next recovery replays the same answer)
        for b in cat.list_branches() {
            if b.transactional && b.state == BranchState::Open {
                cat.set_branch_state(&b.name, BranchState::Aborted)?;
            }
        }
        Ok(cat)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn commit_fixture() -> Commit {
        let mut tables = std::collections::BTreeMap::new();
        tables.insert("t".to_string(), "snap1".to_string());
        Commit::new_at(vec!["p0".into()], tables, "u", "msg", Some("r1".into()), 42)
    }

    #[test]
    fn record_line_roundtrip() {
        let rec = JournalRecord {
            seq: 7,
            op: JournalOp::Commit {
                branch: "main".into(),
                commit: commit_fixture(),
                snapshot: Some(Snapshot::new(vec!["k1".into()], "S", "fp", 3, "r1")),
            },
        };
        let line = rec.to_line();
        assert!(line.ends_with('\n'));
        let back = JournalRecord::from_line(line.trim_end()).unwrap();
        assert_eq!(back, rec);
    }

    #[test]
    fn crc_detects_tampering() {
        let rec = JournalRecord {
            seq: 1,
            op: JournalOp::Tag { name: "v1".into(), target: "c0".into() },
        };
        let line = rec.to_line();
        let tampered = line.replace("v1", "v2");
        assert!(JournalRecord::from_line(tampered.trim_end()).is_err());
    }

    #[test]
    fn all_op_kinds_roundtrip() {
        let ops = vec![
            JournalOp::Replay {
                branch: "dev".into(),
                commits: vec![commit_fixture()],
            },
            JournalOp::BranchCreate {
                info: BranchInfo::transactional("txn/r1", "c0".into(), "r1"),
            },
            JournalOp::SetBranchState { name: "txn/r1".into(), state: BranchState::Aborted },
            JournalOp::BranchDelete { name: "tmp".into() },
            JournalOp::Tag { name: "v1".into(), target: "c9".into() },
            JournalOp::Head { branch: "main".into(), commit: "c3".into() },
            JournalOp::RegisterSnapshot {
                snapshot: Snapshot::new(vec!["o1".into(), "o2".into()], "S", "fp", 9, "r"),
            },
            JournalOp::Gc { pins: vec![] },
            JournalOp::Gc { pins: vec!["snap_a".into(), "snap_b".into()] },
            JournalOp::RunRecord {
                run_id: "run_7".into(),
                record: crate::util::json::Json::obj(vec![
                    ("pipeline", crate::util::json::Json::str("paper_dag")),
                    ("status", crate::util::json::Json::str("success")),
                ]),
            },
        ];
        for (i, op) in ops.into_iter().enumerate() {
            let rec = JournalRecord { seq: i as u64 + 1, op };
            let back = JournalRecord::from_line(rec.to_line().trim_end()).unwrap();
            assert_eq!(back, rec);
        }
    }

    #[test]
    fn journal_scan_stops_at_bad_sequence() {
        let dir = std::env::temp_dir().join(format!("bpl_jseq_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join(JOURNAL_FILE);
        let r1 = JournalRecord { seq: 1, op: JournalOp::Gc { pins: vec![] } };
        let r3 = JournalRecord { seq: 3, op: JournalOp::Gc { pins: vec![] } }; // gap!
        std::fs::write(&path, format!("{}{}", r1.to_line(), r3.to_line())).unwrap();
        let (j, recs) = Journal::open(&path, SyncPolicy::EveryAppend, 0).unwrap();
        assert_eq!(recs.len(), 1);
        assert_eq!(j.last_seq(), 1);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn batch_policy_syncs_less_often() {
        let dir = std::env::temp_dir().join(format!("bpl_jbatch_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        let (mut j, _) =
            Journal::open(dir.join(JOURNAL_FILE), SyncPolicy::Batch(8), 0).unwrap();
        for _ in 0..16 {
            j.append(JournalOp::Gc { pins: vec![] }).unwrap();
        }
        assert_eq!(j.stats().appends, 16);
        assert_eq!(j.stats().syncs, 2);
        j.sync().unwrap();
        assert_eq!(j.stats().syncs, 3);
        let _ = std::fs::remove_dir_all(&dir);
    }
}
