//! Commits: immutable `table -> snapshot` maps with a parent relation.
//!
//! This is Listing 7 of the paper made concrete. A commit is
//! content-addressed over (parents, table map, message-free metadata), so
//! the commit graph is a Merkle DAG exactly like Git's — equal states
//! dedup, and an id proves the entire history below it.

use std::collections::BTreeMap;

use crate::catalog::snapshot::SnapshotId;
use crate::util::id::content_hash_parts;

/// Content-derived commit identifier (hex digest).
pub type CommitId = String;

/// An immutable point-in-time state of the whole lake.
#[derive(Debug, Clone, PartialEq)]
pub struct Commit {
    /// Content address (derived, see [`Commit::new`]).
    pub id: CommitId,
    /// Zero parents for the root, one for a write, two for a merge.
    pub parents: Vec<CommitId>,
    /// The complete table -> snapshot mapping at this commit.
    pub tables: BTreeMap<String, SnapshotId>,
    /// Who created the commit.
    pub author: String,
    /// Human-readable description.
    pub message: String,
    /// Set when the commit was produced by a pipeline run.
    pub run_id: Option<String>,
    /// Wall-clock creation time (excluded from the id; carried by journal
    /// records and checkpoints so recovered state is byte-identical).
    pub timestamp_micros: u64,
}

impl Commit {
    /// Build a commit; the id is derived from parents + tables + author +
    /// message (timestamp excluded so replays of the same logical change
    /// dedup — what makes `merge` idempotent).
    pub fn new(
        parents: Vec<CommitId>,
        tables: BTreeMap<String, SnapshotId>,
        author: &str,
        message: &str,
        run_id: Option<String>,
    ) -> Commit {
        let ts = crate::util::now_micros();
        Commit::new_at(parents, tables, author, message, run_id, ts)
    }

    /// [`Commit::new`] with an explicit timestamp. Used wherever the
    /// clock must not run: the deterministic init commit, journal replay,
    /// and tests.
    pub fn new_at(
        parents: Vec<CommitId>,
        tables: BTreeMap<String, SnapshotId>,
        author: &str,
        message: &str,
        run_id: Option<String>,
        timestamp_micros: u64,
    ) -> Commit {
        let mut parts: Vec<Vec<u8>> = Vec::new();
        for p in &parents {
            parts.push(p.as_bytes().to_vec());
        }
        for (t, s) in &tables {
            parts.push(format!("{t}={s}").into_bytes());
        }
        parts.push(author.as_bytes().to_vec());
        parts.push(message.as_bytes().to_vec());
        if let Some(r) = &run_id {
            parts.push(r.as_bytes().to_vec());
        }
        let refs: Vec<&[u8]> = parts.iter().map(|v| v.as_slice()).collect();
        let id = content_hash_parts(&refs);
        Commit {
            id,
            parents,
            tables,
            author: author.into(),
            message: message.into(),
            run_id,
            timestamp_micros,
        }
    }

    /// The root commit (the model's `Init`): empty lake, no parents, and
    /// a fixed zero timestamp — every fresh catalog starts byte-identical,
    /// which recovery (`load(checkpoint) + replay(journal)`) relies on
    /// when no checkpoint exists yet.
    pub fn init() -> Commit {
        Commit::new_at(vec![], BTreeMap::new(), "system", "Init", None, 0)
    }

    /// Snapshot the given table points at in this commit, if present.
    pub fn snapshot_of(&self, table: &str) -> Option<&SnapshotId> {
        self.tables.get(table)
    }

    /// All table names in this commit (sorted — the map is a BTreeMap).
    pub fn table_names(&self) -> Vec<&str> {
        self.tables.keys().map(|s| s.as_str()).collect()
    }

    /// True for merge commits (more than one parent).
    pub fn is_merge(&self) -> bool {
        self.parents.len() > 1
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn init_is_deterministic() {
        assert_eq!(Commit::init().id, Commit::init().id);
        assert!(Commit::init().parents.is_empty());
        assert!(Commit::init().tables.is_empty());
        // the whole struct, timestamp included — fresh lakes are
        // byte-identical in canonical export
        assert_eq!(Commit::init(), Commit::init());
        assert_eq!(Commit::init().timestamp_micros, 0);
    }

    #[test]
    fn id_covers_tables_and_parents() {
        let mut t1 = BTreeMap::new();
        t1.insert("a".to_string(), "s1".to_string());
        let c1 = Commit::new(vec!["p".into()], t1.clone(), "u", "m", None);
        let c2 = Commit::new(vec!["p".into()], t1.clone(), "u", "m", None);
        assert_eq!(c1.id, c2.id);

        let mut t2 = t1.clone();
        t2.insert("b".to_string(), "s2".to_string());
        let c3 = Commit::new(vec!["p".into()], t2, "u", "m", None);
        assert_ne!(c1.id, c3.id);

        let c4 = Commit::new(vec!["q".into()], t1, "u", "m", None);
        assert_ne!(c1.id, c4.id);
    }

    #[test]
    fn id_excludes_timestamp() {
        let c1 = Commit::new_at(vec![], BTreeMap::new(), "u", "m", None, 1);
        let c2 = Commit::new_at(vec![], BTreeMap::new(), "u", "m", None, 2);
        assert_eq!(c1.id, c2.id);
        assert_ne!(c1.timestamp_micros, c2.timestamp_micros);
    }

    #[test]
    fn merge_commit_detection() {
        let c = Commit::new(vec!["a".into(), "b".into()], BTreeMap::new(), "u", "m", None);
        assert!(c.is_merge());
        assert!(!Commit::init().is_merge());
    }
}
