//! The redesigned commit API: one request type for every commit path.
//!
//! PR 9 collapses the grown-by-accretion trio (`commit_table`,
//! `commit_table_cas`, `commit_table_retrying`) into a single
//! [`CommitRequest`] builder consumed by [`Catalog::commit`]
//! (crate::catalog::Catalog::commit). The local client, the remote
//! client, and the `POST /v1/commit` route all build the same request,
//! so "what happens on conflict" is decided in exactly one place:
//!
//! - [`RetryPolicy::Fail`] — strict CAS. The commit lands only if the
//!   branch head still equals `expected_head`; otherwise the caller gets
//!   the retryable [`BauplanError::CasConflict`]
//!   (crate::error::BauplanError::CasConflict), whose `found` field
//!   carries the *live* head so an informed caller can rebase without
//!   another read.
//! - [`RetryPolicy::Rebase`] — optimistic rebase. On conflict the
//!   catalog re-prepares against the observed live head and tries again,
//!   up to `max_rounds` (unbounded when `None`). Each round's conflict
//!   is *informed*: the validate step returns the head that beat us, so
//!   a round never needs an extra read. With per-round progress
//!   guaranteed (a conflict means some other writer committed), N
//!   same-branch writers converge in at most N rounds.
//!
//! The protocol behind the request — snapshot the head outside the
//! write lock, prepare (clone + hash) outside the write lock, then
//! validate-and-append in a short per-branch critical section — is
//! specified in `doc/CONCURRENCY.md`.

use crate::catalog::commit::CommitId;
use crate::catalog::snapshot::{Snapshot, SnapshotId};

/// What [`Catalog::commit`](crate::catalog::Catalog::commit) does when
/// the branch head moved past the head the request was prepared against.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RetryPolicy {
    /// Strict CAS: surface the conflict as a retryable
    /// [`CasConflict`](crate::error::BauplanError::CasConflict) carrying
    /// the live head.
    Fail,
    /// Re-prepare against the live head and try again, at most
    /// `max_rounds` times (`None` = until the commit lands).
    Rebase {
        /// Give up with the final `CasConflict` after this many retry
        /// rounds; `None` retries until the commit lands.
        max_rounds: Option<u64>,
    },
}

impl RetryPolicy {
    /// Unbounded optimistic rebase (the historical
    /// `commit_table_retrying` behaviour).
    pub fn rebase() -> RetryPolicy {
        RetryPolicy::Rebase { max_rounds: None }
    }
}

/// One table commit, fully described: what to write, where, and how to
/// behave under concurrency. Built with the fluent setters below; only
/// branch, table, and snapshot are mandatory.
#[derive(Debug, Clone)]
pub struct CommitRequest {
    /// Branch whose head the commit advances.
    pub branch: String,
    /// Table the snapshot is published under.
    pub table: String,
    /// The immutable table state being committed.
    pub snapshot: Snapshot,
    /// Commit author (defaults to `"anon"`).
    pub author: String,
    /// Commit message (defaults to `"write <table>"`).
    pub message: String,
    /// Producing run, if the commit belongs to a pipeline run.
    pub run_id: Option<String>,
    /// Head the caller observed; `None` means "prepare against whatever
    /// the head is now".
    pub expected_head: Option<CommitId>,
    /// Conflict behaviour; `None` picks the natural default —
    /// [`RetryPolicy::Fail`] when `expected_head` is pinned (the caller
    /// asserted a precondition), [`RetryPolicy::rebase`] otherwise.
    pub retry: Option<RetryPolicy>,
}

impl CommitRequest {
    /// A request with the defaults documented on each field.
    pub fn new(branch: &str, table: &str, snapshot: Snapshot) -> CommitRequest {
        CommitRequest {
            branch: branch.to_string(),
            table: table.to_string(),
            message: format!("write {table}"),
            snapshot,
            author: "anon".to_string(),
            run_id: None,
            expected_head: None,
            retry: None,
        }
    }

    /// Set the commit author.
    pub fn author(mut self, author: &str) -> CommitRequest {
        self.author = author.to_string();
        self
    }

    /// Set the commit message.
    pub fn message(mut self, message: &str) -> CommitRequest {
        self.message = message.to_string();
        self
    }

    /// Attribute the commit to a pipeline run.
    pub fn run_id(mut self, run_id: Option<String>) -> CommitRequest {
        self.run_id = run_id;
        self
    }

    /// Pin the head this commit must apply on top of (makes the default
    /// policy strict CAS).
    pub fn expected_head(mut self, head: &str) -> CommitRequest {
        self.expected_head = Some(head.to_string());
        self
    }

    /// Explicit conflict policy, overriding the default derived from
    /// `expected_head`.
    pub fn retry(mut self, policy: RetryPolicy) -> CommitRequest {
        self.retry = Some(policy);
        self
    }

    /// The policy [`Catalog::commit`](crate::catalog::Catalog::commit)
    /// runs under: the explicit one, or the `expected_head`-derived
    /// default.
    pub fn effective_retry(&self) -> RetryPolicy {
        match self.retry {
            Some(p) => p,
            None if self.expected_head.is_some() => RetryPolicy::Fail,
            None => RetryPolicy::rebase(),
        }
    }
}

/// What a successful [`Catalog::commit`](crate::catalog::Catalog::commit)
/// produced.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct CommitOutcome {
    /// Id of the commit that now heads the branch.
    pub commit: CommitId,
    /// Id of the snapshot the commit published.
    pub snapshot: SnapshotId,
    /// Conflict rounds the commit survived before landing (0 when
    /// uncontended; always 0 under [`RetryPolicy::Fail`]).
    pub retries: u64,
}

#[cfg(test)]
mod tests {
    use super::*;

    fn snap() -> Snapshot {
        Snapshot::new(vec!["obj".into()], "S", "fp", 1, "r")
    }

    #[test]
    fn defaults_are_rebase_without_expected_head() {
        let r = CommitRequest::new("main", "t", snap());
        assert_eq!(r.author, "anon");
        assert_eq!(r.message, "write t");
        assert_eq!(r.effective_retry(), RetryPolicy::rebase());
    }

    #[test]
    fn pinning_a_head_defaults_to_strict_cas() {
        let r = CommitRequest::new("main", "t", snap()).expected_head("abc");
        assert_eq!(r.effective_retry(), RetryPolicy::Fail);
        // and an explicit policy always wins
        let r = r.retry(RetryPolicy::Rebase { max_rounds: Some(3) });
        assert_eq!(r.effective_retry(), RetryPolicy::Rebase { max_rounds: Some(3) });
    }

    #[test]
    fn setters_thread_through() {
        let r = CommitRequest::new("dev", "t", snap())
            .author("u")
            .message("m")
            .run_id(Some("r1".into()));
        assert_eq!((r.author.as_str(), r.message.as_str()), ("u", "m"));
        assert_eq!(r.run_id.as_deref(), Some("r1"));
    }
}
