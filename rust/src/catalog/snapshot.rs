//! Immutable table snapshots — the atomic versioned objects.
//!
//! A snapshot is the Iceberg analogue: an ordered list of immutable data
//! objects (content-addressed batch blobs in the object store) plus the
//! schema metadata and the id of the run that produced it. Snapshots are
//! themselves content-addressed, so identical table states are one
//! object no matter how many branches reference them.

use crate::util::id::content_hash_parts;

/// Content-derived snapshot identifier (hex digest).
pub type SnapshotId = String;

/// One immutable version of one table.
#[derive(Debug, Clone, PartialEq)]
pub struct Snapshot {
    /// Content address (derived, see [`Snapshot::new`]).
    pub id: SnapshotId,
    /// Object-store keys of the data batches, in order.
    pub objects: Vec<String>,
    /// Name of the schema the data was validated against.
    pub schema_name: String,
    /// Schema fingerprint at write time (drift detection).
    pub schema_fingerprint: String,
    /// Valid rows across all batches.
    pub row_count: u64,
    /// The run that wrote this snapshot — the consistency predicate of
    /// E3/E4 and of the model checker keys on this.
    pub run_id: String,
}

impl Snapshot {
    /// Build a snapshot; the id is content-derived from every field, so
    /// identical table states are one object across branches.
    pub fn new(
        objects: Vec<String>,
        schema_name: &str,
        schema_fingerprint: &str,
        row_count: u64,
        run_id: &str,
    ) -> Snapshot {
        let mut parts: Vec<&[u8]> = vec![
            schema_name.as_bytes(),
            schema_fingerprint.as_bytes(),
            run_id.as_bytes(),
        ];
        for o in &objects {
            parts.push(o.as_bytes());
        }
        let rc = row_count.to_le_bytes();
        parts.push(&rc);
        let id = content_hash_parts(&parts);
        Snapshot {
            id,
            objects,
            schema_name: schema_name.into(),
            schema_fingerprint: schema_fingerprint.into(),
            row_count,
            run_id: run_id.into(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn id_is_content_derived() {
        let a = Snapshot::new(vec!["k1".into()], "S", "fp", 10, "run_a");
        let b = Snapshot::new(vec!["k1".into()], "S", "fp", 10, "run_a");
        assert_eq!(a.id, b.id);
        let c = Snapshot::new(vec!["k2".into()], "S", "fp", 10, "run_a");
        assert_ne!(a.id, c.id);
        // same bytes, different writer run => different snapshot identity
        let d = Snapshot::new(vec!["k1".into()], "S", "fp", 10, "run_b");
        assert_ne!(a.id, d.id);
    }

    #[test]
    fn object_order_matters() {
        let a = Snapshot::new(vec!["k1".into(), "k2".into()], "S", "fp", 1, "r");
        let b = Snapshot::new(vec!["k2".into(), "k1".into()], "S", "fp", 1, "r");
        assert_ne!(a.id, b.id);
    }
}
