//! The catalog service: linearizable ref store over immutable commits,
//! with an optional durable commit journal.
//!
//! Commits run optimistically (the stand-in for the relational database
//! with optimistic locks that backs Iceberg/Nessie in real Bauplan —
//! paper §3.2): a committer snapshots the branch head under a read
//! lock, prepares its record — table-map clone, content hash — outside
//! every lock, then validates-and-publishes in a short critical section
//! keyed per branch (see `doc/CONCURRENCY.md`). Writers to disjoint
//! branches contend only for the brief map-update window; same-branch
//! writers serialize on their branch lock and conflicts surface as the
//! retryable [`BauplanError::CasConflict`] carrying the live head.
//! Readers take a consistent view of a ref with a read lock and then
//! never block: commits are immutable.
//!
//! When a journal is attached (via [`Catalog::recover`] /
//! [`Catalog::open_durable`](crate::catalog::Catalog::open_durable)),
//! every mutator follows the write-ahead discipline specified in
//! `doc/COMMIT_PIPELINE.md`:
//!
//! 1. **lock** — take the branch lock, then the catalog write lock;
//! 2. **append** — write the mutation's physical record to the journal;
//! 3. **apply** — mutate the in-memory maps;
//! 4. **publish** — release the lock;
//! 5. **sync** — wait until an fsync covers the record, per the
//!    journal's [`SyncPolicy`](crate::catalog::journal::SyncPolicy).
//!    Under [`SyncPolicy::GroupCommit`](crate::catalog::journal::SyncPolicy::GroupCommit)
//!    this wait happens *outside* the catalog locks: one waiter becomes
//!    the leader and fsyncs the whole enqueued batch, so concurrent
//!    committers amortize the sync.
//!
//! A failed append aborts the mutation before step 3, so no state is ever
//! observable that the journal cannot reproduce
//! (`journal_append_failure_blocks_the_write` below proves the ordering).
//!
//! Steps 4–5 leave a deliberate, documented **read-before-durable
//! window**: between publish and the covering fsync, readers can observe
//! a mutation that a crash would revoke (recovery lands on the last
//! synced prefix — the crash matrix exercises exactly this window).
//! That is the group-commit trade: an *acknowledged* call is always
//! crash-durable, but concurrent readers run slightly ahead of the disk.
//! If the covering fsync ever **fails**, the window cannot be closed:
//! the mutation is applied and visible but the journal cannot reproduce
//! it. The failing waiter then *poisons* the catalog
//! ([`Catalog::is_poisoned`]) — every later mutation is refused with
//! [`BauplanError::Poisoned`], the API server answers 503, and the only
//! recovery is to reopen the lake with [`Catalog::recover`]
//! (`failed_group_sync_poisons_the_catalog` below proves the sequence).
//! Every applied mutation is also marked in an in-memory change log, which
//! is what [`Catalog::checkpoint`] flushes as an incremental delta
//! snapshot — O(changes), not O(history).

use std::collections::{BTreeMap, BTreeSet, HashMap, HashSet, VecDeque};
use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex, RwLock};

use crate::catalog::commit::{Commit, CommitId};
use crate::catalog::commit_api::{CommitOutcome, CommitRequest, RetryPolicy};
use crate::catalog::journal::{
    CrashPoint, Journal, JournalOp, JournalRecord, JournalStats, RecoveryStats, SyncTicket,
};
use crate::catalog::refs::{BranchInfo, BranchState, RefName};
use crate::catalog::snapshot::{Snapshot, SnapshotId};
use crate::catalog::{persist, MAIN, TXN_PREFIX};
use crate::error::{BauplanError, Result};
use crate::merge::{compute_merge, MergeOutcome};
use crate::storage::ObjectStore;
use crate::trace::{FlightRecorder, DEFAULT_FLIGHT_CAP};
use crate::util::json::Json;

/// Table-level difference between two commits.
#[derive(Debug, Clone, PartialEq)]
pub enum TableDiff {
    /// Table exists in `to` but not in `from`.
    Added(String, SnapshotId),
    /// Table exists in `from` but not in `to`.
    Removed(String, SnapshotId),
    /// Table points at different snapshots on the two sides.
    Changed {
        /// Table name.
        table: String,
        /// Snapshot on the `from` side.
        from: SnapshotId,
        /// Snapshot on the `to` side.
        to: SnapshotId,
    },
}

#[derive(Default)]
struct Inner {
    commits: HashMap<CommitId, Commit>,
    snapshots: HashMap<SnapshotId, Snapshot>,
    branches: HashMap<RefName, BranchInfo>,
    tags: HashMap<RefName, CommitId>,
    /// Refcounted GC roots for snapshots referenced outside the commit
    /// graph (the run cache pins every memoized snapshot so it survives
    /// branch deletion). Not journaled: pins are cache-lifecycle state,
    /// re-established from the cache index on attach — the `gc` journal
    /// record carries the pin roots it ran with, so replay stays
    /// deterministic.
    pins: HashMap<SnapshotId, u64>,
    /// Terminal run records (`run_id -> opaque JSON`), journaled and
    /// checkpointed like refs so `get_run` survives a process restart.
    /// The catalog stores them opaquely — the run engine owns the codec
    /// (layering: `runs` depends on `catalog`, never the reverse).
    runs: HashMap<String, Json>,
    /// Span traces of terminal runs (`run_id -> opaque JSON`), stored
    /// beside the run records with the same ownership split: the tracing
    /// layer owns the codec (and the span cap), the catalog only makes
    /// it durable so `bauplan trace <run-id>` works after a restart.
    traces: HashMap<String, Json>,
    /// Everything mutated since the last checkpoint — the "memtable
    /// index" that incremental delta checkpoints flush. Populated on
    /// every successful journal append and on recovery replay; cleared
    /// when a delta or base snapshot captures it.
    changes: ChangeLog,
}

/// Ids touched since the last checkpoint, so a delta snapshot can be
/// built in O(changes). Upsert-only for commits/snapshots/tags/runs
/// (those are only ever *removed* by GC, which forces the next
/// checkpoint to compact into a full base instead); branches also track
/// deletions explicitly.
#[derive(Default)]
struct ChangeLog {
    commits: BTreeSet<CommitId>,
    snapshots: BTreeSet<SnapshotId>,
    branches: BTreeSet<RefName>,
    branches_deleted: BTreeSet<RefName>,
    tags: BTreeSet<RefName>,
    runs: BTreeSet<String>,
    traces: BTreeSet<String>,
    /// A GC sweep ran: deltas cannot express its deletions, so the next
    /// checkpoint promotes itself to a full compaction.
    swept: bool,
}

impl ChangeLog {
    fn clear(&mut self) {
        *self = ChangeLog::default();
    }

    fn is_empty(&self) -> bool {
        !self.swept
            && self.commits.is_empty()
            && self.snapshots.is_empty()
            && self.branches.is_empty()
            && self.branches_deleted.is_empty()
            && self.tags.is_empty()
            && self.runs.is_empty()
            && self.traces.is_empty()
    }
}

/// The durability slot: where the lake lives on disk, its journal, and
/// the snapshot-chain bookkeeping.
struct Durability {
    dir: PathBuf,
    journal: Journal,
    /// Last journal sequence number the snapshot chain (base + deltas)
    /// covers; recovery replays only records above it.
    covered_seq: u64,
    /// Delta snapshots written since the last base — when this reaches
    /// the journal config's threshold, `checkpoint()` compacts.
    deltas_since_base: u64,
    /// What the last recovery actually read (tail-bounded evidence).
    recovery: RecoveryStats,
}

/// One consistent, sorted dump of the entire catalog state — taken under
/// a single read lock, so exports and checkpoints can never observe a
/// half-applied mutation.
pub(crate) struct StateDump {
    /// All commits, sorted by id.
    pub commits: Vec<(CommitId, Commit)>,
    /// All snapshots, sorted by id.
    pub snapshots: Vec<(SnapshotId, Snapshot)>,
    /// All branches, sorted by name.
    pub branches: Vec<BranchInfo>,
    /// All tags, sorted by name.
    pub tags: Vec<(RefName, CommitId)>,
    /// All terminal run records, sorted by run id.
    pub runs: Vec<(String, Json)>,
    /// All journaled run traces, sorted by run id.
    pub traces: Vec<(String, Json)>,
}

/// The Git-for-data catalog. Cheap to clone (Arc inside).
#[derive(Clone)]
pub struct Catalog {
    inner: Arc<RwLock<Inner>>,
    store: Arc<ObjectStore>,
    /// One lock per branch name (created on first use): the short
    /// critical section every branch-head mutation runs in, so
    /// same-branch writers serialize while disjoint-branch writers
    /// proceed concurrently. Correctness never depends on lock
    /// *identity* — the head re-validation under the `inner` write lock
    /// is what makes commits linearizable — so dropping an entry when
    /// its branch is deleted is safe even if a straggler still holds
    /// the old `Arc`. Lock order: branch lock → `inner` → `durability`;
    /// no mutator ever holds two branch locks.
    branch_locks: Arc<Mutex<HashMap<RefName, Arc<Mutex<()>>>>>,
    /// `Some` once a journal is attached; lock order is always
    /// `inner` → `durability` (mutators hold the write lock when they
    /// append, `checkpoint`/`compact` hold it across the whole flush),
    /// so the pair can never deadlock and the journal sees mutations in
    /// lock order.
    durability: Arc<Mutex<Option<Durability>>>,
    /// Set when a durability wait (group-commit fsync) failed after its
    /// mutation was already applied: the in-memory state may be ahead of
    /// the journal, so every further mutation is refused with
    /// [`BauplanError::Poisoned`] until the lake is reopened with
    /// [`Catalog::recover`]. See `is_poisoned` for the read-side
    /// contract.
    poisoned: Arc<AtomicBool>,
    /// Ring buffer of recent catalog operations (the flight recorder).
    /// Run spans are journaled with their run; everything the catalog
    /// does outside a run lands here, and the ring is dumped to
    /// `<lake>/flight/` when a group-commit fsync poisons the catalog.
    flight: FlightRecorder,
}

impl Catalog {
    /// Fresh catalog: root commit + `main` branch (the model's `Init` +
    /// `Main`). In-memory only — attach durability with
    /// [`Catalog::recover`].
    pub fn new(store: Arc<ObjectStore>) -> Catalog {
        let mut inner = Inner::default();
        let init = Commit::init();
        let init_id = init.id.clone();
        inner.commits.insert(init_id.clone(), init);
        inner
            .branches
            .insert(MAIN.into(), BranchInfo::normal(MAIN, init_id));
        Catalog {
            inner: Arc::new(RwLock::new(inner)),
            store,
            branch_locks: Arc::new(Mutex::new(HashMap::new())),
            durability: Arc::new(Mutex::new(None)),
            poisoned: Arc::new(AtomicBool::new(false)),
            flight: FlightRecorder::new(DEFAULT_FLIGHT_CAP),
        }
    }

    /// The per-branch serialization point (created on first use). Every
    /// branch-head mutation holds this across validate-and-publish; see
    /// the field doc on `branch_locks` for the ordering rules.
    fn branch_lock(&self, branch: &str) -> Arc<Mutex<()>> {
        let mut locks = self.branch_locks.lock().unwrap();
        locks.entry(branch.to_string()).or_default().clone()
    }

    /// The object store this catalog's snapshots point into.
    pub fn store(&self) -> &Arc<ObjectStore> {
        &self.store
    }

    /// The catalog's flight recorder (recent non-run operations). The
    /// API server shares this handle for its request spans, so one dump
    /// interleaves catalog and HTTP activity in arrival order.
    pub fn flight(&self) -> &FlightRecorder {
        &self.flight
    }

    // ------------------------------------------------------------ journal

    /// Append `op` to the journal, if one is attached. Called by every
    /// mutator *while holding the write lock*, *before* the mutation is
    /// applied — the write-ahead step of the commit pipeline. On success
    /// the op is marked in the change log and the caller receives the
    /// sync ticket it must wait on *after* releasing the lock.
    fn journal_append(&self, inner: &mut Inner, op: JournalOp) -> Result<SyncTicket> {
        if self.poisoned.load(Ordering::SeqCst) {
            return Err(BauplanError::Poisoned(
                "a group-commit fsync failed; reopen with Catalog::recover".into(),
            ));
        }
        let mut g = self.durability.lock().unwrap();
        match g.as_mut() {
            Some(d) => {
                let mut fs = self.flight.begin("catalog.journal_append");
                fs.attr_str("op", op.name());
                match d.journal.append(&op) {
                    Ok((seq, ticket)) => {
                        drop(g);
                        fs.attr_u64("seq", seq);
                        fs.finish();
                        Self::mark_changes(&mut inner.changes, &op);
                        Ok(ticket)
                    }
                    Err(e) => {
                        fs.fail(e.to_string());
                        Err(e)
                    }
                }
            }
            None => Ok(SyncTicket::Done),
        }
    }

    /// Block until the mutation's journal record is durable (commit-
    /// pipeline step 5, after the locks are released). If the wait fails
    /// — the group-commit leader's fsync refused — the mutation is
    /// already applied and visible, so the catalog is marked poisoned:
    /// every further mutation is refused and [`Catalog::is_poisoned`]
    /// reports it (the API server turns this into 503s), bounding how
    /// long anyone can keep acting on state the journal cannot
    /// reproduce. The only way out is [`Catalog::recover`].
    fn await_durable(&self, ticket: SyncTicket) -> Result<()> {
        match ticket.wait() {
            Ok(()) => Ok(()),
            Err(e) => {
                self.poisoned.store(true, Ordering::SeqCst);
                // post-mortem first, error second: record the poisoning
                // in the flight ring and dump it beside the lake. Both
                // are best-effort — triage evidence must never turn one
                // failure into two.
                let mut fs = self.flight.begin("catalog.poisoned");
                fs.fail(e.to_string());
                fs.finish();
                if let Some(dir) = self.durable_dir() {
                    let _ = self.flight.dump(&dir, "catalog poisoned");
                }
                Err(e)
            }
        }
    }

    /// Has a durability wait failed after its mutation was applied?
    ///
    /// While `false`, every state a reader observes is either durable or
    /// will be durable before the mutator's call returns (the documented
    /// read-before-durable window of group commit: a reader may see a
    /// commit whose fsync is still in flight, and a crash inside that
    /// window revokes it on recovery — exactly the window the crash
    /// matrix exercises via `debug_lose_unsynced_tail`). Once `true`,
    /// that promise is broken for good: in-memory state is ahead of the
    /// journal, mutations are refused, and long-lived embedders should
    /// stop serving reads and reopen with [`Catalog::recover`] — the API
    /// server checks this flag per request and answers 503.
    pub fn is_poisoned(&self) -> bool {
        self.poisoned.load(Ordering::SeqCst)
    }

    /// Debug hook (tests): make the next group-commit leader fsync fail,
    /// driving the poison path without a real disk fault. No-op when not
    /// durable.
    pub fn debug_fail_next_group_sync(&self) {
        if let Some(d) = self.durability.lock().unwrap().as_mut() {
            d.journal.debug_fail_next_group_sync();
        }
    }

    /// Record which ids `op` touches, so the next delta checkpoint can
    /// flush exactly the changed entries. Runs only after the journal
    /// accepted the record (a refused append must not poison the delta).
    fn mark_changes(log: &mut ChangeLog, op: &JournalOp) {
        match op {
            JournalOp::Commit { branch, commit, snapshot } => {
                log.commits.insert(commit.id.clone());
                log.branches.insert(branch.clone());
                if let Some(s) = snapshot {
                    log.snapshots.insert(s.id.clone());
                }
            }
            JournalOp::Replay { branch, commits } => {
                for c in commits {
                    log.commits.insert(c.id.clone());
                }
                log.branches.insert(branch.clone());
            }
            JournalOp::BranchCreate { info } => {
                log.branches.insert(info.name.clone());
                // a re-created branch is an upsert, not a deletion
                log.branches_deleted.remove(&info.name);
            }
            JournalOp::SetBranchState { name, .. } => {
                log.branches.insert(name.clone());
            }
            JournalOp::BranchDelete { name } => {
                log.branches_deleted.insert(name.clone());
                log.branches.remove(name);
            }
            JournalOp::Tag { name, .. } => {
                log.tags.insert(name.clone());
            }
            JournalOp::Head { branch, .. } => {
                log.branches.insert(branch.clone());
            }
            JournalOp::RegisterSnapshot { snapshot } => {
                log.snapshots.insert(snapshot.id.clone());
            }
            JournalOp::Gc { .. } => {
                log.swept = true;
            }
            JournalOp::RunRecord { run_id, .. } => {
                log.runs.insert(run_id.clone());
            }
            JournalOp::RunTrace { run_id, .. } => {
                log.traces.insert(run_id.clone());
            }
        }
    }

    /// Bind a recovered journal to this catalog (recovery step 4), with
    /// the snapshot chain's covered floor, its delta count, and the
    /// recovery evidence.
    pub(crate) fn attach_durability(
        &self,
        dir: PathBuf,
        journal: Journal,
        covered_seq: u64,
        deltas_since_base: u64,
        recovery: RecoveryStats,
    ) {
        *self.durability.lock().unwrap() =
            Some(Durability { dir, journal, covered_seq, deltas_since_base, recovery });
    }

    /// Is a journal attached?
    pub fn is_durable(&self) -> bool {
        self.durability.lock().unwrap().is_some()
    }

    /// The durable lake directory, if this catalog was opened with
    /// [`Catalog::recover`].
    pub fn durable_dir(&self) -> Option<PathBuf> {
        self.durability.lock().unwrap().as_ref().map(|d| d.dir.clone())
    }

    /// Journal counters (appends / syncs / bytes / last seq), if durable.
    pub fn journal_stats(&self) -> Option<JournalStats> {
        self.durability.lock().unwrap().as_ref().map(|d| d.journal.stats())
    }

    /// Force batched journal appends to stable storage (group-durability
    /// flush; a no-op for [`SyncPolicy::EveryAppend`](crate::catalog::journal::SyncPolicy)
    /// and for non-durable catalogs).
    pub fn journal_sync(&self) -> Result<()> {
        if let Some(d) = self.durability.lock().unwrap().as_mut() {
            d.journal.sync()?;
        }
        Ok(())
    }

    /// Crash-point injection (see
    /// [`Journal::inject_fail_after`](crate::catalog::journal::Journal::inject_fail_after)):
    /// after `n` more successful appends, every journal append fails as if
    /// the process died mid-write. No-op when not durable.
    pub fn journal_inject_fail_after(&self, n: u64) {
        if let Some(d) = self.durability.lock().unwrap().as_mut() {
            d.journal.inject_fail_after(n);
        }
    }

    /// Write an incremental checkpoint: flush the change log as one
    /// immutable delta snapshot covering everything up to the current
    /// journal sequence number (memtable → SST). Returns the covered
    /// sequence number. Cost is O(changes since the last checkpoint) —
    /// not O(history).
    ///
    /// Promotes itself to a full [`Catalog::compact`] when a GC sweep ran
    /// (deltas are upsert-only and cannot express its deletions) or when
    /// the delta chain reached the configured length. Holds the write
    /// lock across the dump *and* the snapshot write, so no mutation can
    /// slip between "state captured" and "floor advanced".
    pub fn checkpoint(&self) -> Result<u64> {
        let mut inner = self.inner.write().unwrap();
        let mut dur_g = self.durability.lock().unwrap();
        let d = dur_g.as_mut().ok_or_else(|| {
            BauplanError::Other("checkpoint: catalog has no journal attached".into())
        })?;
        d.journal.sync()?;
        let seq = d.journal.last_seq();
        if seq == d.covered_seq && inner.changes.is_empty() {
            return Ok(seq); // nothing new since the last checkpoint
        }
        if inner.changes.swept
            || d.deltas_since_base >= d.journal.config().compact_after_deltas
        {
            return Self::compact_locked(&mut inner, d);
        }
        if d.journal.crash_armed(CrashPoint::MidDeltaFlush) {
            // journal synced, delta never published: recovery replays the
            // journal tail and loses nothing
            return Err(d.journal.trip_crash());
        }
        let delta = Self::delta_json_locked(&inner, d.covered_seq, seq);
        persist::write_delta(&d.dir, &delta, d.covered_seq, seq)?;
        d.covered_seq = seq;
        d.deltas_since_base += 1;
        inner.changes.clear();
        Ok(seq)
    }

    /// Fold the snapshot chain into a fresh base snapshot, rotate the
    /// active journal segment, and retire every journal segment the new
    /// base fully covers. Returns the covered sequence number.
    ///
    /// This is the LSM compaction step: O(state) — the expensive path
    /// [`Catalog::checkpoint`] runs only when it must. Safe at every
    /// crash point: the base is published atomically (newest base wins on
    /// recovery), stale deltas are ignored by the chain reader, and
    /// segments are retired only after the base covering them is durable.
    pub fn compact(&self) -> Result<u64> {
        let mut inner = self.inner.write().unwrap();
        let mut dur_g = self.durability.lock().unwrap();
        let d = dur_g.as_mut().ok_or_else(|| {
            BauplanError::Other("compact: catalog has no journal attached".into())
        })?;
        Self::compact_locked(&mut inner, d)
    }

    fn compact_locked(inner: &mut Inner, d: &mut Durability) -> Result<u64> {
        d.journal.sync()?;
        let seq = d.journal.last_seq();
        let export = persist::export_json(&Self::dump_locked(inner));
        persist::write_base(&d.dir, &export, seq)?;
        if d.journal.crash_armed(CrashPoint::MidCompactBase) {
            // base published; stale deltas/segments survive until the
            // next compaction — recovery picks the newest base and
            // ignores everything it covers
            return Err(d.journal.trip_crash());
        }
        persist::remove_stale_snapshots(&d.dir, seq);
        d.journal.rotate_if_nonempty()?;
        if d.journal.crash_armed(CrashPoint::MidCompactRetire) {
            return Err(d.journal.trip_crash());
        }
        d.journal.retire_covered(seq)?;
        d.covered_seq = seq;
        d.deltas_since_base = 0;
        inner.changes.clear();
        Ok(seq)
    }

    /// Build the delta snapshot body for `(from, to]` from the change
    /// log: cloned upserts of every touched entry plus explicit branch
    /// deletions.
    fn delta_json_locked(inner: &Inner, from: u64, to: u64) -> Json {
        let ch = &inner.changes;
        let mut commits = BTreeMap::new();
        for id in &ch.commits {
            if let Some(c) = inner.commits.get(id) {
                commits.insert(id.clone(), persist::commit_to_json(c));
            }
        }
        let mut snapshots = BTreeMap::new();
        for id in &ch.snapshots {
            if let Some(s) = inner.snapshots.get(id) {
                snapshots.insert(id.clone(), persist::snapshot_to_json(s));
            }
        }
        let mut branches = BTreeMap::new();
        for name in &ch.branches {
            if let Some(b) = inner.branches.get(name) {
                branches.insert(name.clone(), persist::branch_to_json(b));
            }
        }
        let mut tags = BTreeMap::new();
        for name in &ch.tags {
            if let Some(t) = inner.tags.get(name) {
                tags.insert(name.clone(), Json::str(t));
            }
        }
        let mut runs = BTreeMap::new();
        for id in &ch.runs {
            if let Some(r) = inner.runs.get(id) {
                runs.insert(id.clone(), r.clone());
            }
        }
        let mut traces = BTreeMap::new();
        for id in &ch.traces {
            if let Some(t) = inner.traces.get(id) {
                traces.insert(id.clone(), t.clone());
            }
        }
        Json::obj(vec![
            ("version", Json::num(1.0)),
            ("from_seq", Json::num(from as f64)),
            ("to_seq", Json::num(to as f64)),
            (
                "upserts",
                Json::obj(vec![
                    ("commits", Json::Obj(commits)),
                    ("snapshots", Json::Obj(snapshots)),
                    ("branches", Json::Obj(branches)),
                    ("tags", Json::Obj(tags)),
                    ("runs", Json::Obj(runs)),
                    ("traces", Json::Obj(traces)),
                ]),
            ),
            (
                "branches_deleted",
                Json::Arr(ch.branches_deleted.iter().map(Json::str).collect()),
            ),
        ])
    }

    /// Apply one delta snapshot from the chain (recovery step 2):
    /// upserts, then branch deletions. Idempotent and ordered, exactly
    /// like journal replay.
    pub(crate) fn apply_snapshot_delta(&self, delta: &persist::SnapshotDelta) -> Result<()> {
        let mut inner = self.inner.write().unwrap();
        let u = delta.json.get("upserts");
        if let Some(cs) = u.get("commits").as_obj() {
            for (id, cj) in cs {
                inner.commits.insert(id.clone(), persist::commit_from_json(id, cj));
            }
        }
        if let Some(ss) = u.get("snapshots").as_obj() {
            for (id, sj) in ss {
                inner.snapshots.insert(id.clone(), persist::snapshot_from_json(id, sj));
            }
        }
        if let Some(bs) = u.get("branches").as_obj() {
            for (name, bj) in bs {
                inner.branches.insert(name.clone(), persist::branch_from_json(name, bj)?);
            }
        }
        if let Some(ts) = u.get("tags").as_obj() {
            for (name, t) in ts {
                inner.tags.insert(name.clone(), t.as_str().unwrap_or("").to_string());
            }
        }
        if let Some(rs) = u.get("runs").as_obj() {
            for (id, r) in rs {
                inner.runs.insert(id.clone(), r.clone());
            }
        }
        if let Some(ts) = u.get("traces").as_obj() {
            for (id, t) in ts {
                inner.traces.insert(id.clone(), t.clone());
            }
        }
        for name in delta.json.get("branches_deleted").as_arr().unwrap_or(&[]) {
            if let Some(n) = name.as_str() {
                inner.branches.remove(n);
            }
        }
        Ok(())
    }

    /// Seal the active journal segment and start a fresh one (no-op when
    /// the active segment is empty or the catalog is not durable). The
    /// simulator fires this mid-trace to exercise recovery across
    /// segment boundaries.
    pub fn journal_rotate(&self) -> Result<()> {
        if let Some(d) = self.durability.lock().unwrap().as_mut() {
            d.journal.rotate_if_nonempty()?;
        }
        Ok(())
    }

    /// What the last [`Catalog::recover`] actually read — the evidence
    /// for the tail-bounded recovery claim. `None` when not durable.
    pub fn recovery_stats(&self) -> Option<RecoveryStats> {
        self.durability.lock().unwrap().as_ref().map(|d| d.recovery)
    }

    /// Journal floor currently covered by the snapshot chain (tests).
    pub fn covered_seq(&self) -> Option<u64> {
        self.durability.lock().unwrap().as_ref().map(|d| d.covered_seq)
    }

    /// Arm a [`CrashPoint`] for the crash-matrix harness: the next
    /// operation reaching the point fails as if the process died there
    /// and the journal is poisoned. No-op when not durable.
    pub fn inject_crash_point(&self, p: CrashPoint) {
        if let Some(d) = self.durability.lock().unwrap().as_mut() {
            d.journal.inject_crash_point(p);
        }
    }

    /// Simulate power loss for the group-commit enqueue-vs-fsync window:
    /// truncate the active segment to its last fsynced length and poison
    /// the journal (crash-matrix harness). No-op when not durable.
    pub fn debug_lose_unsynced_tail(&self) -> Result<()> {
        if let Some(d) = self.durability.lock().unwrap().as_mut() {
            d.journal.debug_lose_unsynced_tail()?;
        }
        Ok(())
    }

    /// Apply one replayed journal record (recovery step 3). Replay is
    /// ordered and idempotent — and *tolerant*: a record may reference a
    /// branch the checkpoint already saw deleted (the crash window
    /// between `catalog.json` and `checkpoint.json` leaves a stale
    /// floor, so already-applied records replay again). Every arm
    /// therefore treats "branch missing" as "effect already subsumed by
    /// the checkpoint" and skips the head move; commits and snapshots
    /// still insert (idempotent, and they keep tags resolvable).
    pub(crate) fn apply_journal_record(&self, rec: &JournalRecord) -> Result<()> {
        {
            // replayed records are changes the snapshot chain has not
            // captured yet — the next delta checkpoint must include them
            let mut inner = self.inner.write().unwrap();
            Self::mark_changes(&mut inner.changes, &rec.op);
        }
        match &rec.op {
            JournalOp::Commit { branch, commit, snapshot } => {
                let mut inner = self.inner.write().unwrap();
                if let Some(s) = snapshot {
                    inner.snapshots.entry(s.id.clone()).or_insert_with(|| s.clone());
                }
                inner.commits.insert(commit.id.clone(), commit.clone());
                if let Some(b) = inner.branches.get_mut(branch) {
                    b.head = commit.id.clone();
                }
            }
            JournalOp::Replay { branch, commits } => {
                let mut inner = self.inner.write().unwrap();
                for c in commits {
                    inner.commits.insert(c.id.clone(), c.clone());
                }
                let head = commits.last().expect("validated non-empty").id.clone();
                if let Some(b) = inner.branches.get_mut(branch) {
                    b.head = head;
                }
            }
            JournalOp::BranchCreate { info } => {
                let mut inner = self.inner.write().unwrap();
                inner.branches.insert(info.name.clone(), info.clone());
            }
            JournalOp::SetBranchState { name, state } => {
                let mut inner = self.inner.write().unwrap();
                // tolerant: the branch may already be deleted by a later,
                // checkpoint-covered record
                if let Some(b) = inner.branches.get_mut(name) {
                    b.state = *state;
                }
            }
            JournalOp::BranchDelete { name } => {
                let mut inner = self.inner.write().unwrap();
                inner.branches.remove(name);
            }
            JournalOp::Tag { name, target } => {
                let mut inner = self.inner.write().unwrap();
                inner.tags.insert(name.clone(), target.clone());
            }
            JournalOp::Head { branch, commit } => {
                let mut inner = self.inner.write().unwrap();
                if let Some(b) = inner.branches.get_mut(branch) {
                    b.head = commit.clone();
                }
            }
            JournalOp::RegisterSnapshot { snapshot } => {
                let mut inner = self.inner.write().unwrap();
                inner
                    .snapshots
                    .entry(snapshot.id.clone())
                    .or_insert_with(|| snapshot.clone());
            }
            JournalOp::Gc { pins } => {
                // replay with the pin roots the original sweep used —
                // never the (empty, not-yet-reattached) live pins
                let mut inner = self.inner.write().unwrap();
                Self::sweep_locked(&mut inner, &self.store, pins);
            }
            JournalOp::RunRecord { run_id, record } => {
                let mut inner = self.inner.write().unwrap();
                inner.runs.insert(run_id.clone(), record.clone());
            }
            JournalOp::RunTrace { run_id, trace } => {
                let mut inner = self.inner.write().unwrap();
                inner.traces.insert(run_id.clone(), trace.clone());
            }
        }
        Ok(())
    }

    // ------------------------------------------------------------ resolve

    /// Resolve a ref (branch name, tag name, or commit id) to a commit id.
    pub fn resolve(&self, r: &str) -> Result<CommitId> {
        let inner = self.inner.read().unwrap();
        Self::resolve_locked(&inner, r)
    }

    fn resolve_locked(inner: &Inner, r: &str) -> Result<CommitId> {
        if let Some(b) = inner.branches.get(r) {
            return Ok(b.head.clone());
        }
        if let Some(c) = inner.tags.get(r) {
            return Ok(c.clone());
        }
        if inner.commits.contains_key(r) {
            return Ok(r.to_string());
        }
        Err(BauplanError::UnknownRef(r.to_string()))
    }

    /// Read the full commit a ref points at (snapshot-isolated view: the
    /// returned commit is immutable).
    pub fn read_ref(&self, r: &str) -> Result<Commit> {
        let inner = self.inner.read().unwrap();
        let id = Self::resolve_locked(&inner, r)?;
        Ok(inner.commits[&id].clone())
    }

    /// Fetch a commit by id.
    pub fn get_commit(&self, id: &str) -> Result<Commit> {
        let inner = self.inner.read().unwrap();
        inner
            .commits
            .get(id)
            .cloned()
            .ok_or_else(|| BauplanError::UnknownRef(id.to_string()))
    }

    /// Fetch a snapshot by id.
    pub fn get_snapshot(&self, id: &str) -> Result<Snapshot> {
        let inner = self.inner.read().unwrap();
        inner
            .snapshots
            .get(id)
            .cloned()
            .ok_or_else(|| BauplanError::ObjectNotFound(format!("snapshot {id}")))
    }

    // ------------------------------------------------------------ branches

    /// Create a branch at the commit `from` resolves to.
    ///
    /// Enforces the Fig. 4 visibility guardrail: if `from` is an *aborted
    /// transactional* branch, the fork is refused unless `allow_aborted`
    /// (the paper's deliberate escape hatch for idempotent re-runs).
    pub fn create_branch(
        &self,
        name: &str,
        from: &str,
        allow_aborted: bool,
    ) -> Result<BranchInfo> {
        let blk = self.branch_lock(name);
        let _bg = blk.lock().unwrap();
        let mut inner = self.inner.write().unwrap();
        if inner.branches.contains_key(name) || inner.tags.contains_key(name) {
            return Err(BauplanError::RefExists(name.to_string()));
        }
        if let Some(src) = inner.branches.get(from) {
            if !src.freely_visible() && !allow_aborted {
                return Err(BauplanError::Visibility(format!(
                    "branch '{from}' is an aborted transactional branch; \
                     fork requires allow_aborted")));
            }
        }
        let head = Self::resolve_locked(&inner, from)?;
        let info = if name.starts_with(TXN_PREFIX) {
            // run engine passes owner separately via create_txn_branch
            BranchInfo::transactional(name, head, "")
        } else {
            BranchInfo::normal(name, head)
        };
        let ticket =
            self.journal_append(&mut inner, JournalOp::BranchCreate { info: info.clone() })?;
        inner.branches.insert(name.into(), info.clone());
        drop(inner);
        self.await_durable(ticket)?;
        Ok(info)
    }

    /// Create the transactional branch for a run (namespaced, owned).
    pub fn create_txn_branch(&self, target: &str, run_id: &str) -> Result<BranchInfo> {
        let name = format!("{TXN_PREFIX}{run_id}");
        let blk = self.branch_lock(&name);
        let _bg = blk.lock().unwrap();
        let mut inner = self.inner.write().unwrap();
        if inner.branches.contains_key(&name) {
            return Err(BauplanError::RefExists(name));
        }
        let head = Self::resolve_locked(&inner, target)?;
        let info = BranchInfo::transactional(&name, head, run_id);
        let ticket =
            self.journal_append(&mut inner, JournalOp::BranchCreate { info: info.clone() })?;
        inner.branches.insert(name, info.clone());
        drop(inner);
        self.await_durable(ticket)?;
        Ok(info)
    }

    /// Metadata of one branch.
    pub fn branch_info(&self, name: &str) -> Result<BranchInfo> {
        let inner = self.inner.read().unwrap();
        inner
            .branches
            .get(name)
            .cloned()
            .ok_or_else(|| BauplanError::UnknownRef(name.to_string()))
    }

    /// All branches, sorted by name.
    pub fn list_branches(&self) -> Vec<BranchInfo> {
        let inner = self.inner.read().unwrap();
        let mut v: Vec<_> = inner.branches.values().cloned().collect();
        v.sort_by(|a, b| a.name.cmp(&b.name));
        v
    }

    /// Delete a branch (never `main`).
    pub fn delete_branch(&self, name: &str) -> Result<()> {
        if name == MAIN {
            return Err(BauplanError::Other("cannot delete main".into()));
        }
        let blk = self.branch_lock(name);
        let bg = blk.lock().unwrap();
        let mut inner = self.inner.write().unwrap();
        if !inner.branches.contains_key(name) {
            return Err(BauplanError::UnknownRef(name.to_string()));
        }
        let ticket = self
            .journal_append(&mut inner, JournalOp::BranchDelete { name: name.to_string() })?;
        inner.branches.remove(name);
        drop(inner);
        drop(bg);
        // bound the registry: a recreated branch gets a fresh lock, and
        // correctness never depends on lock identity (see branch_locks)
        self.branch_locks.lock().unwrap().remove(name);
        self.await_durable(ticket)?;
        Ok(())
    }

    /// Transition a transactional branch's lifecycle state (run engine).
    pub fn set_branch_state(&self, name: &str, state: BranchState) -> Result<()> {
        let blk = self.branch_lock(name);
        let _bg = blk.lock().unwrap();
        let mut inner = self.inner.write().unwrap();
        if !inner.branches.contains_key(name) {
            return Err(BauplanError::UnknownRef(name.to_string()));
        }
        let ticket = self.journal_append(
            &mut inner,
            JournalOp::SetBranchState { name: name.to_string(), state },
        )?;
        inner.branches.get_mut(name).unwrap().state = state;
        drop(inner);
        self.await_durable(ticket)?;
        Ok(())
    }

    // ------------------------------------------------------------ tags

    /// Create an immutable tag at the commit `target` resolves to.
    pub fn tag(&self, name: &str, target: &str) -> Result<CommitId> {
        let mut inner = self.inner.write().unwrap();
        if inner.tags.contains_key(name) || inner.branches.contains_key(name) {
            return Err(BauplanError::RefExists(name.to_string()));
        }
        let id = Self::resolve_locked(&inner, target)?;
        let ticket = self.journal_append(
            &mut inner,
            JournalOp::Tag { name: name.to_string(), target: id.clone() },
        )?;
        inner.tags.insert(name.into(), id.clone());
        drop(inner);
        self.await_durable(ticket)?;
        Ok(id)
    }

    // ------------------------------------------------------------ run records

    /// Durably record a terminal run state (opaque JSON owned by the run
    /// engine). Write-ahead journaled like every other mutation, and
    /// included in checkpoints, so `get_run` works after a restart.
    /// Idempotent per `run_id`: a re-put overwrites.
    pub fn put_run_record(&self, run_id: &str, record: Json) -> Result<()> {
        let mut inner = self.inner.write().unwrap();
        let ticket = self.journal_append(
            &mut inner,
            JournalOp::RunRecord { run_id: run_id.to_string(), record: record.clone() },
        )?;
        inner.runs.insert(run_id.to_string(), record);
        drop(inner);
        self.await_durable(ticket)?;
        Ok(())
    }

    /// Fetch a terminal run record by run id.
    pub fn get_run_record(&self, run_id: &str) -> Option<Json> {
        self.inner.read().unwrap().runs.get(run_id).cloned()
    }

    /// All terminal run records, sorted by run id.
    pub fn run_records(&self) -> Vec<(String, Json)> {
        let inner = self.inner.read().unwrap();
        let mut v: Vec<_> = inner.runs.iter().map(|(k, r)| (k.clone(), r.clone())).collect();
        v.sort_by(|a, b| a.0.cmp(&b.0));
        v
    }

    /// Bulk-load run records (persistence import; bypasses the journal
    /// exactly like [`Catalog::restore`], which runs before a journal is
    /// attached).
    pub(crate) fn set_run_records(&self, runs: Vec<(String, Json)>) {
        let mut inner = self.inner.write().unwrap();
        inner.runs = runs.into_iter().collect();
    }

    /// Durably record a terminal run's span trace (opaque JSON owned by
    /// the tracing layer — already capped and truncation-counted there).
    /// Same pipeline as [`Catalog::put_run_record`]: write-ahead
    /// journaled, checkpointed, idempotent per `run_id`.
    pub fn put_run_trace(&self, run_id: &str, trace: Json) -> Result<()> {
        let mut inner = self.inner.write().unwrap();
        let ticket = self.journal_append(
            &mut inner,
            JournalOp::RunTrace { run_id: run_id.to_string(), trace: trace.clone() },
        )?;
        inner.traces.insert(run_id.to_string(), trace);
        drop(inner);
        self.await_durable(ticket)?;
        Ok(())
    }

    /// Fetch a journaled run trace by run id.
    pub fn get_run_trace(&self, run_id: &str) -> Option<Json> {
        self.inner.read().unwrap().traces.get(run_id).cloned()
    }

    /// All journaled run traces, sorted by run id.
    pub fn run_traces(&self) -> Vec<(String, Json)> {
        let inner = self.inner.read().unwrap();
        let mut v: Vec<_> =
            inner.traces.iter().map(|(k, t)| (k.clone(), t.clone())).collect();
        v.sort_by(|a, b| a.0.cmp(&b.0));
        v
    }

    /// Bulk-load run traces (persistence import; bypasses the journal
    /// exactly like [`Catalog::set_run_records`]).
    pub(crate) fn set_run_traces(&self, traces: Vec<(String, Json)>) {
        let mut inner = self.inner.write().unwrap();
        inner.traces = traces.into_iter().collect();
    }

    // ------------------------------------------------------------ writes

    /// Register a snapshot (its data objects must already be in the
    /// store). Idempotent: re-registering an id is a no-op and is not
    /// re-journaled.
    pub fn register_snapshot(&self, snap: Snapshot) -> Result<SnapshotId> {
        let mut inner = self.inner.write().unwrap();
        let id = snap.id.clone();
        if inner.snapshots.contains_key(&id) {
            return Ok(id);
        }
        let ticket = self
            .journal_append(&mut inner, JournalOp::RegisterSnapshot { snapshot: snap.clone() })?;
        inner.snapshots.insert(id.clone(), snap);
        drop(inner);
        self.await_durable(ticket)?;
        Ok(id)
    }

    /// THE mutating operation (paper Listing 8 / `createTable`), behind
    /// the one [`CommitRequest`] every commit path builds: allocate a
    /// fresh commit `co` with `co.parent = head(branch)`, the table map
    /// updated with `table -> snapshot`, and advance the branch to `co`.
    ///
    /// Optimistic protocol (`doc/CONCURRENCY.md`): the head is observed
    /// under a read lock, the record is prepared — table-map clone,
    /// content hash — outside every lock, and only validate-and-publish
    /// runs in the per-branch critical section. The head the request was
    /// prepared against is re-validated there; if it moved, the request's
    /// [`RetryPolicy`] decides between the retryable
    /// [`BauplanError::CasConflict`] (whose `found` field carries the
    /// live head, so an informed caller rebases without another read)
    /// and an in-catalog rebase round against that same live head.
    pub fn commit(&self, req: CommitRequest) -> Result<CommitOutcome> {
        let policy = req.effective_retry();
        let snap_id = req.snapshot.id.clone();
        let (commit, retries) = self.commit_occ(
            &req.branch,
            req.expected_head.clone(),
            policy,
            &req.author,
            &req.message,
            req.run_id.clone(),
            Some(&req.snapshot),
            |tables| {
                tables.insert(req.table.clone(), snap_id.clone());
                Ok(())
            },
        )?;
        Ok(CommitOutcome { commit, snapshot: snap_id, retries })
    }

    /// The read / prepare / validate-and-publish loop shared by
    /// [`Catalog::commit`] and [`Catalog::delete_table`]. `edit` rewrites
    /// the parent's table map (re-run per rebase round); `snapshot` is
    /// journaled iff this commit introduces it. Returns the new commit id
    /// and the number of conflict rounds survived.
    #[allow(clippy::too_many_arguments)]
    fn commit_occ(
        &self,
        branch: &str,
        expected_head: Option<CommitId>,
        policy: RetryPolicy,
        author: &str,
        message: &str,
        run_id: Option<String>,
        snapshot: Option<&Snapshot>,
        edit: impl Fn(&mut BTreeMap<String, SnapshotId>) -> Result<()>,
    ) -> Result<(CommitId, u64)> {
        let mut pinned = expected_head;
        let mut retries = 0u64;
        loop {
            // read: observe a base head without blocking other writers
            let base = match pinned.take() {
                Some(h) => h,
                None => {
                    let inner = self.inner.read().unwrap();
                    inner
                        .branches
                        .get(branch)
                        .ok_or_else(|| BauplanError::UnknownRef(branch.to_string()))?
                        .head
                        .clone()
                }
            };
            // prepare: clone + edit + hash, outside every lock — the work
            // the old single-write-lock path serialized globally
            let mut tables = {
                let inner = self.inner.read().unwrap();
                match inner.commits.get(&base) {
                    Some(c) => c.tables.clone(),
                    // a pinned head that is not even a commit can only
                    // lose the CAS: report it against the live head
                    None => {
                        let found = inner
                            .branches
                            .get(branch)
                            .ok_or_else(|| BauplanError::UnknownRef(branch.to_string()))?
                            .head
                            .clone();
                        return Err(BauplanError::CasConflict {
                            reference: branch.to_string(),
                            expected: base,
                            found,
                        });
                    }
                }
            };
            edit(&mut tables)?;
            let commit = Commit::new(vec![base.clone()], tables, author, message, run_id.clone());
            let id = commit.id.clone();
            // validate-and-publish: the short per-branch critical section
            let blk = self.branch_lock(branch);
            let bg = blk.lock().unwrap();
            let mut inner = self.inner.write().unwrap();
            let live = inner
                .branches
                .get(branch)
                .ok_or_else(|| BauplanError::UnknownRef(branch.to_string()))?
                .head
                .clone();
            if live != base {
                drop(inner);
                drop(bg);
                let conflict = BauplanError::CasConflict {
                    reference: branch.to_string(),
                    expected: base,
                    found: live.clone(),
                };
                match policy {
                    RetryPolicy::Fail => return Err(conflict),
                    RetryPolicy::Rebase { max_rounds } => {
                        retries += 1;
                        if let Some(max) = max_rounds {
                            if retries > max {
                                return Err(conflict);
                            }
                        }
                        // informed rebase: validation told us the live
                        // head, so the next round needs no extra read
                        pinned = Some(live);
                        continue;
                    }
                }
            }
            let journal_snapshot = match snapshot {
                Some(s) if !inner.snapshots.contains_key(&s.id) => Some(s.clone()),
                _ => None,
            };
            let ticket = self.journal_append(
                &mut inner,
                JournalOp::Commit {
                    branch: branch.to_string(),
                    commit: commit.clone(),
                    snapshot: journal_snapshot,
                },
            )?;
            if let Some(s) = snapshot {
                inner.snapshots.entry(s.id.clone()).or_insert_with(|| s.clone());
            }
            inner.commits.insert(id.clone(), commit);
            inner.branches.get_mut(branch).unwrap().head = id.clone();
            drop(inner);
            drop(bg);
            // the durability wait runs outside every lock, so disjoint-
            // branch commits share one group-commit fsync batch
            self.await_durable(ticket)?;
            return Ok((id, retries));
        }
    }

    /// Deprecated shim: unconditional publish on the current head.
    #[deprecated(note = "build a CommitRequest and call Catalog::commit")]
    pub fn commit_table(
        &self,
        branch: &str,
        table: &str,
        snapshot: Snapshot,
        author: &str,
        message: &str,
        run_id: Option<String>,
    ) -> Result<CommitId> {
        self.commit(
            CommitRequest::new(branch, table, snapshot)
                .author(author)
                .message(message)
                .run_id(run_id)
                .retry(RetryPolicy::rebase()),
        )
        .map(|o| o.commit)
    }

    /// Deprecated shim: strict CAS against `expected_head`.
    #[deprecated(note = "build a CommitRequest with expected_head and call Catalog::commit")]
    pub fn commit_table_cas(
        &self,
        branch: &str,
        expected_head: &str,
        table: &str,
        snapshot: Snapshot,
        author: &str,
        message: &str,
        run_id: Option<String>,
    ) -> Result<CommitId> {
        self.commit(
            CommitRequest::new(branch, table, snapshot)
                .author(author)
                .message(message)
                .run_id(run_id)
                .expected_head(expected_head),
        )
        .map(|o| o.commit)
    }

    /// Deprecated shim: optimistic rebase until the commit lands. The
    /// historical version re-read the head at the top of every round —
    /// under the same lock it was racing on; the unified path rebases on
    /// the live head the failed validation itself returned.
    #[deprecated(note = "build a CommitRequest with RetryPolicy::rebase and call Catalog::commit")]
    pub fn commit_table_retrying(
        &self,
        branch: &str,
        table: &str,
        snapshot: Snapshot,
        author: &str,
        message: &str,
        run_id: Option<String>,
    ) -> Result<(CommitId, u64)> {
        self.commit(
            CommitRequest::new(branch, table, snapshot)
                .author(author)
                .message(message)
                .run_id(run_id)
                .retry(RetryPolicy::rebase()),
        )
        .map(|o| (o.commit, o.retries))
    }

    /// Drop a table from a branch (a commit that removes the mapping).
    /// Runs the same optimistic validate-and-publish loop as
    /// [`Catalog::commit`], rebasing across concurrent commits.
    pub fn delete_table(
        &self,
        branch: &str,
        table: &str,
        author: &str,
        run_id: Option<String>,
    ) -> Result<CommitId> {
        let (id, _retries) = self.commit_occ(
            branch,
            None,
            RetryPolicy::rebase(),
            author,
            &format!("drop table {table}"),
            run_id,
            None,
            |tables| match tables.remove(table) {
                Some(_) => Ok(()),
                None => Err(BauplanError::TableNotFound(table.to_string())),
            },
        )?;
        Ok(id)
    }

    // ------------------------------------------------------------ merge

    /// Merge `src` into branch `dst` (paper §3.2/§3.3).
    ///
    /// Fast-forwards when possible; otherwise builds a three-way merge
    /// commit from the lowest common ancestor. Table-level conflicts
    /// (both sides changed the same table differently) abort with
    /// [`BauplanError::MergeConflict`]. Zero-copy: only pointers move.
    ///
    /// Durably atomic: the merge is one journal record — after a crash it
    /// either replays whole or never happened; a half-merged state is
    /// unrepresentable.
    ///
    /// Guardrail: merging an aborted transactional branch requires
    /// `allow_aborted` (the Fig. 4 counterexample is exactly this merge).
    pub fn merge(&self, src: &str, dst: &str, allow_aborted: bool) -> Result<CommitId> {
        // only dst's head moves, so only dst's branch lock is taken —
        // never two at once (the no-deadlock rule on branch_locks)
        let blk = self.branch_lock(dst);
        let _bg = blk.lock().unwrap();
        let mut inner = self.inner.write().unwrap();
        if let Some(b) = inner.branches.get(src) {
            if !b.freely_visible() && !allow_aborted {
                return Err(BauplanError::Visibility(format!(
                    "branch '{src}' is an aborted transactional branch; \
                     merge requires allow_aborted")));
            }
        }
        let src_id = Self::resolve_locked(&inner, src)?;
        let dst_info = inner
            .branches
            .get(dst)
            .ok_or_else(|| BauplanError::UnknownRef(dst.to_string()))?
            .clone();
        let dst_id = dst_info.head.clone();

        if src_id == dst_id {
            return Ok(dst_id); // nothing to do
        }
        if Self::is_ancestor_locked(&inner, &src_id, &dst_id) {
            return Ok(dst_id); // src already contained
        }
        if Self::is_ancestor_locked(&inner, &dst_id, &src_id) {
            // fast-forward: move the pointer, no new commit
            let ticket = self.journal_append(
                &mut inner,
                JournalOp::Head { branch: dst.to_string(), commit: src_id.clone() },
            )?;
            inner.branches.get_mut(dst).unwrap().head = src_id.clone();
            drop(inner);
            self.await_durable(ticket)?;
            return Ok(src_id);
        }
        let base_id = Self::lca_locked(&inner, &src_id, &dst_id).ok_or_else(|| {
            BauplanError::MergeConflict("no common ancestor".into())
        })?;
        let base = inner.commits[&base_id].clone();
        let src_c = inner.commits[&src_id].clone();
        let dst_c = inner.commits[&dst_id].clone();
        match compute_merge(&base, &src_c, &dst_c)? {
            MergeOutcome::AlreadyMerged => Ok(dst_id),
            MergeOutcome::Merged(tables) => {
                let commit = Commit::new(
                    vec![dst_id, src_id],
                    tables,
                    "merge",
                    &format!("merge {src} into {dst}"),
                    None,
                );
                let id = commit.id.clone();
                let ticket = self.journal_append(
                    &mut inner,
                    JournalOp::Commit {
                        branch: dst.to_string(),
                        commit: commit.clone(),
                        snapshot: None,
                    },
                )?;
                inner.commits.insert(id.clone(), commit);
                inner.branches.get_mut(dst).unwrap().head = id.clone();
                drop(inner);
                self.await_durable(ticket)?;
                Ok(id)
            }
        }
    }

    // ------------------------------------------------------------ history

    /// First-parent history from a ref (newest first), up to `limit`.
    pub fn log(&self, r: &str, limit: usize) -> Result<Vec<Commit>> {
        let inner = self.inner.read().unwrap();
        let mut id = Self::resolve_locked(&inner, r)?;
        let mut out = Vec::new();
        while out.len() < limit {
            let c = &inner.commits[&id];
            out.push(c.clone());
            match c.parents.first() {
                Some(p) => id = p.clone(),
                None => break,
            }
        }
        Ok(out)
    }

    /// Is `anc` an ancestor of (or equal to) `desc`?
    pub fn is_ancestor(&self, anc: &str, desc: &str) -> Result<bool> {
        let inner = self.inner.read().unwrap();
        let a = Self::resolve_locked(&inner, anc)?;
        let d = Self::resolve_locked(&inner, desc)?;
        Ok(Self::is_ancestor_locked(&inner, &a, &d))
    }

    fn is_ancestor_locked(inner: &Inner, anc: &CommitId, desc: &CommitId) -> bool {
        let mut queue = VecDeque::from([desc.clone()]);
        let mut seen = HashSet::new();
        while let Some(id) = queue.pop_front() {
            if &id == anc {
                return true;
            }
            if !seen.insert(id.clone()) {
                continue;
            }
            if let Some(c) = inner.commits.get(&id) {
                queue.extend(c.parents.iter().cloned());
            }
        }
        false
    }

    /// Lowest common ancestor (BFS depth heuristic; commit graphs here
    /// are small enough for exact behaviour to match Git's merge-base in
    /// all the shapes the run protocol produces).
    fn lca_locked(inner: &Inner, a: &CommitId, b: &CommitId) -> Option<CommitId> {
        let ancestors_a = Self::all_ancestors(inner, a);
        // BFS from b, first hit in ancestors_a is a lowest common ancestor
        let mut queue = VecDeque::from([b.clone()]);
        let mut seen = HashSet::new();
        while let Some(id) = queue.pop_front() {
            if ancestors_a.contains(&id) {
                return Some(id);
            }
            if !seen.insert(id.clone()) {
                continue;
            }
            if let Some(c) = inner.commits.get(&id) {
                queue.extend(c.parents.iter().cloned());
            }
        }
        None
    }

    fn all_ancestors(inner: &Inner, from: &CommitId) -> HashSet<CommitId> {
        let mut out = HashSet::new();
        let mut queue = VecDeque::from([from.clone()]);
        while let Some(id) = queue.pop_front() {
            if !out.insert(id.clone()) {
                continue;
            }
            if let Some(c) = inner.commits.get(&id) {
                queue.extend(c.parents.iter().cloned());
            }
        }
        out
    }

    /// Table-level diff between two refs (what a data PR review shows).
    pub fn diff(&self, from: &str, to: &str) -> Result<Vec<TableDiff>> {
        let a = self.read_ref(from)?;
        let b = self.read_ref(to)?;
        let mut out = Vec::new();
        for (t, s) in &b.tables {
            match a.tables.get(t) {
                None => out.push(TableDiff::Added(t.clone(), s.clone())),
                Some(prev) if prev != s => out.push(TableDiff::Changed {
                    table: t.clone(),
                    from: prev.clone(),
                    to: s.clone(),
                }),
                _ => {}
            }
        }
        for (t, s) in &a.tables {
            if !b.tables.contains_key(t) {
                out.push(TableDiff::Removed(t.clone(), s.clone()));
            }
        }
        Ok(out)
    }

    // ------------------------------------------------------------ replay

    /// Apply a sequence of table-map deltas as fresh commits on `branch`
    /// — all or nothing, under one write lock (rebase/cherry-pick core).
    /// Journaled as a single record, so the batch is also all-or-nothing
    /// across a crash.
    pub(crate) fn apply_deltas(
        &self,
        branch: &str,
        deltas: &[(crate::merge::rebase::Delta, String, Option<String>)],
    ) -> Result<CommitId> {
        let blk = self.branch_lock(branch);
        let _bg = blk.lock().unwrap();
        let mut inner = self.inner.write().unwrap();
        let mut head = inner
            .branches
            .get(branch)
            .ok_or_else(|| BauplanError::UnknownRef(branch.to_string()))?
            .head
            .clone();
        let mut new_commits: Vec<Commit> = Vec::with_capacity(deltas.len());
        for (delta, message, run_id) in deltas {
            let tables_base = match new_commits.last() {
                Some(c) => c.tables.clone(),
                None => inner.commits[&head].tables.clone(),
            };
            let mut tables = tables_base;
            delta.apply(&mut tables);
            let commit =
                Commit::new(vec![head.clone()], tables, "replay", message, run_id.clone());
            head = commit.id.clone();
            new_commits.push(commit);
        }
        if new_commits.is_empty() {
            return Ok(head);
        }
        let ticket = self.journal_append(
            &mut inner,
            JournalOp::Replay { branch: branch.to_string(), commits: new_commits.clone() },
        )?;
        for c in new_commits {
            inner.commits.insert(c.id.clone(), c);
        }
        inner.branches.get_mut(branch).unwrap().head = head.clone();
        drop(inner);
        self.await_durable(ticket)?;
        Ok(head)
    }

    /// Move a branch pointer to an existing commit (rebase epilogue).
    pub(crate) fn force_branch(&self, branch: &str, commit: &str) -> Result<()> {
        let blk = self.branch_lock(branch);
        let _bg = blk.lock().unwrap();
        let mut inner = self.inner.write().unwrap();
        if !inner.commits.contains_key(commit) {
            return Err(BauplanError::UnknownRef(commit.to_string()));
        }
        if !inner.branches.contains_key(branch) {
            return Err(BauplanError::UnknownRef(branch.to_string()));
        }
        let ticket = self.journal_append(
            &mut inner,
            JournalOp::Head { branch: branch.to_string(), commit: commit.to_string() },
        )?;
        inner.branches.get_mut(branch).unwrap().head = commit.to_string();
        drop(inner);
        self.await_durable(ticket)?;
        Ok(())
    }

    // ------------------------------------------------------------ persist/gc

    /// One consistent dump of everything, under a single read lock.
    pub(crate) fn dump_state(&self) -> StateDump {
        let inner = self.inner.read().unwrap();
        Self::dump_locked(&inner)
    }

    fn dump_locked(inner: &Inner) -> StateDump {
        let mut commits: Vec<_> =
            inner.commits.iter().map(|(k, c)| (k.clone(), c.clone())).collect();
        commits.sort_by(|a, b| a.0.cmp(&b.0));
        let mut snapshots: Vec<_> =
            inner.snapshots.iter().map(|(k, s)| (k.clone(), s.clone())).collect();
        snapshots.sort_by(|a, b| a.0.cmp(&b.0));
        let mut branches: Vec<_> = inner.branches.values().cloned().collect();
        branches.sort_by(|a, b| a.name.cmp(&b.name));
        let mut tags: Vec<_> =
            inner.tags.iter().map(|(k, c)| (k.clone(), c.clone())).collect();
        tags.sort();
        let mut runs: Vec<_> =
            inner.runs.iter().map(|(k, r)| (k.clone(), r.clone())).collect();
        runs.sort_by(|a, b| a.0.cmp(&b.0));
        let mut traces: Vec<_> =
            inner.traces.iter().map(|(k, t)| (k.clone(), t.clone())).collect();
        traces.sort_by(|a, b| a.0.cmp(&b.0));
        StateDump { commits, snapshots, branches, tags, runs, traces }
    }

    /// All commits (persistence export; cloned, immutable).
    pub fn dump_commits(&self) -> Vec<(CommitId, Commit)> {
        self.dump_state().commits
    }

    /// All snapshots (persistence export).
    pub fn dump_snapshots(&self) -> Vec<(SnapshotId, Snapshot)> {
        self.dump_state().snapshots
    }

    /// All tags (persistence export).
    pub fn dump_tags(&self) -> Vec<(RefName, CommitId)> {
        self.dump_state().tags
    }

    /// Replace the catalog state wholesale (persistence import). Every
    /// branch head and tag must resolve to an imported commit; `main`
    /// must exist. Refused on a durable catalog — a wholesale swap would
    /// bypass the journal (recovery performs the import *before* the
    /// journal is attached).
    pub fn restore(
        &self,
        commits: Vec<Commit>,
        snapshots: Vec<Snapshot>,
        branches: Vec<BranchInfo>,
        tags: Vec<(RefName, CommitId)>,
    ) -> Result<()> {
        if self.is_durable() {
            return Err(BauplanError::Other(
                "restore: refusing wholesale state swap on a journaled catalog \
                 (open a fresh one, or checkpoint + recover)"
                    .into(),
            ));
        }
        let mut inner = self.inner.write().unwrap();
        let commit_ids: HashSet<&str> = commits.iter().map(|c| c.id.as_str()).collect();
        if !branches.iter().any(|b| b.name == MAIN) {
            return Err(BauplanError::Parse("import: no main branch".into()));
        }
        for b in &branches {
            if !commit_ids.contains(b.head.as_str()) {
                return Err(BauplanError::Parse(format!(
                    "import: branch '{}' head {} not among commits",
                    b.name, b.head
                )));
            }
        }
        for (name, target) in &tags {
            if !commit_ids.contains(target.as_str()) {
                return Err(BauplanError::Parse(format!(
                    "import: tag '{name}' target not among commits")));
            }
        }
        inner.commits = commits.into_iter().map(|c| (c.id.clone(), c)).collect();
        inner.snapshots = snapshots.into_iter().map(|s| (s.id.clone(), s)).collect();
        inner.branches = branches.into_iter().map(|b| (b.name.clone(), b)).collect();
        inner.tags = tags.into_iter().collect();
        Ok(())
    }

    // ------------------------------------------------------------ pins

    /// Pin a snapshot as a GC root independent of the commit graph (the
    /// run cache pins every memoized snapshot so eviction, not branch
    /// deletion, decides its lifetime). Refcounted; fails if the
    /// snapshot is unknown so a stale cache entry cannot acquire a pin.
    pub fn pin_snapshot(&self, id: &str) -> Result<()> {
        let mut inner = self.inner.write().unwrap();
        if !inner.snapshots.contains_key(id) {
            return Err(BauplanError::ObjectNotFound(format!("snapshot {id}")));
        }
        *inner.pins.entry(id.to_string()).or_insert(0) += 1;
        Ok(())
    }

    /// Release one pin on a snapshot (no-op when not pinned).
    pub fn unpin_snapshot(&self, id: &str) {
        let mut inner = self.inner.write().unwrap();
        if let Some(n) = inner.pins.get_mut(id) {
            *n -= 1;
            if *n == 0 {
                inner.pins.remove(id);
            }
        }
    }

    /// Current pin refcount of a snapshot (tests/CLI).
    pub fn pin_count(&self, id: &str) -> u64 {
        self.inner.read().unwrap().pins.get(id).copied().unwrap_or(0)
    }

    /// Garbage collection: drop commits and snapshots unreachable from
    /// any branch, tag, or pinned snapshot, then sweep the object store.
    /// Returns (commits_dropped, snapshots_dropped, objects_dropped,
    /// bytes_freed).
    ///
    /// Aborted transactional branches count as roots — the paper keeps
    /// them reachable "for debugging and inspection" until explicitly
    /// deleted, so GC must not eat the triage evidence. Pinned snapshots
    /// count as roots too, so the run cache's entries survive deletion
    /// of the branches that produced them.
    ///
    /// Journaled as a single `gc` record *before* the sweep. The record
    /// carries the pin roots the sweep ran with: pins themselves are not
    /// journaled, so embedding them keeps replay deterministic — a
    /// recovered catalog re-runs the identical mark-and-sweep.
    pub fn gc(&self) -> Result<(usize, usize, usize, u64)> {
        let mut inner = self.inner.write().unwrap();
        let mut pins: Vec<SnapshotId> = inner.pins.keys().cloned().collect();
        pins.sort(); // canonical record content
        let ticket = self.journal_append(&mut inner, JournalOp::Gc { pins: pins.clone() })?;
        let swept = Self::sweep_locked(&mut inner, &self.store, &pins);
        drop(inner);
        self.await_durable(ticket)?;
        Ok(swept)
    }

    /// The deterministic mark-and-sweep, parameterized by the pin roots
    /// (live pins for a fresh gc, the journal record's pins on replay).
    fn sweep_locked(
        inner: &mut Inner,
        store: &ObjectStore,
        pins: &[SnapshotId],
    ) -> (usize, usize, usize, u64) {
        // mark
        let mut live_commits: HashSet<CommitId> = HashSet::new();
        let mut queue: VecDeque<CommitId> = inner
            .branches
            .values()
            .map(|b| b.head.clone())
            .chain(inner.tags.values().cloned())
            .collect();
        while let Some(id) = queue.pop_front() {
            if !live_commits.insert(id.clone()) {
                continue;
            }
            if let Some(c) = inner.commits.get(&id) {
                queue.extend(c.parents.iter().cloned());
            }
        }
        let mut live_snaps: HashSet<SnapshotId> = live_commits
            .iter()
            .filter_map(|c| inner.commits.get(c))
            .flat_map(|c| c.tables.values().cloned())
            .collect();
        live_snaps.extend(pins.iter().cloned());
        let live_objects: HashSet<String> = live_snaps
            .iter()
            .filter_map(|s| inner.snapshots.get(s))
            .flat_map(|s| s.objects.iter().cloned())
            .collect();
        // sweep
        let commits_before = inner.commits.len();
        let snaps_before = inner.snapshots.len();
        inner.commits.retain(|id, _| live_commits.contains(id));
        inner.snapshots.retain(|id, _| live_snaps.contains(id));
        let (objects_dropped, bytes) = store.retain(&live_objects);
        (
            commits_before - inner.commits.len(),
            snaps_before - inner.snapshots.len(),
            objects_dropped,
            bytes,
        )
    }

    /// Counters for benches: (commits, snapshots, branches, tags).
    pub fn sizes(&self) -> (usize, usize, usize, usize) {
        let inner = self.inner.read().unwrap();
        (
            inner.commits.len(),
            inner.snapshots.len(),
            inner.branches.len(),
            inner.tags.len(),
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testing::{commit_table, commit_table_cas, commit_table_retrying};

    fn catalog() -> Catalog {
        Catalog::new(Arc::new(ObjectStore::new()))
    }

    fn snap(tag: &str, run: &str) -> Snapshot {
        Snapshot::new(vec![format!("obj_{tag}")], "S", "fp", 1, run)
    }

    #[test]
    fn starts_with_main_at_init() {
        let c = catalog();
        let main = c.read_ref(MAIN).unwrap();
        assert!(main.tables.is_empty());
        assert!(main.parents.is_empty());
    }

    #[test]
    fn commit_table_advances_branch() {
        let c = catalog();
        let before = c.resolve(MAIN).unwrap();
        let id = commit_table(&c, MAIN, "t", snap("a", "r1"), "u", "write t", Some("r1".into()))
            .unwrap();
        assert_ne!(before, id);
        let head = c.read_ref(MAIN).unwrap();
        assert_eq!(head.id, id);
        assert!(head.tables.contains_key("t"));
        assert_eq!(head.parents, vec![before]);
    }

    #[test]
    fn branch_is_isolated_from_source() {
        let c = catalog();
        commit_table(&c, MAIN, "t", snap("a", "r1"), "u", "m", None).unwrap();
        c.create_branch("dev", MAIN, false).unwrap();
        commit_table(&c, "dev", "t", snap("b", "r2"), "u", "m", None).unwrap();
        let main_t = c.read_ref(MAIN).unwrap().tables["t"].clone();
        let dev_t = c.read_ref("dev").unwrap().tables["t"].clone();
        assert_ne!(main_t, dev_t);
        assert_eq!(main_t, snap("a", "r1").id);
    }

    #[test]
    fn branch_creation_is_zero_copy() {
        let c = catalog();
        for i in 0..20 {
            commit_table(&c, MAIN, &format!("t{i}"), snap(&format!("{i}"), "r"), "u", "m", None)
                .unwrap();
        }
        let (commits_before, snaps_before, _, _) = c.sizes();
        c.create_branch("dev", MAIN, false).unwrap();
        let (commits_after, snaps_after, _, _) = c.sizes();
        assert_eq!(commits_before, commits_after); // no data, no commits copied
        assert_eq!(snaps_before, snaps_after);
    }

    #[test]
    fn cas_conflict_detected() {
        let c = catalog();
        let head = c.resolve(MAIN).unwrap();
        commit_table(&c, MAIN, "t", snap("a", "r1"), "u", "m", None).unwrap();
        let err = commit_table_cas(&c, MAIN, &head, "t", snap("b", "r2"), "u", "m", None)
            .unwrap_err();
        assert!(matches!(err, BauplanError::CasConflict { .. }));
    }

    #[test]
    fn fast_forward_merge_moves_pointer() {
        let c = catalog();
        c.create_branch("dev", MAIN, false).unwrap();
        commit_table(&c, "dev", "t", snap("a", "r1"), "u", "m", None).unwrap();
        let dev_head = c.resolve("dev").unwrap();
        let merged = c.merge("dev", MAIN, false).unwrap();
        assert_eq!(merged, dev_head);
        assert_eq!(c.resolve(MAIN).unwrap(), dev_head);
    }

    #[test]
    fn three_way_merge_combines_disjoint_tables() {
        let c = catalog();
        commit_table(&c, MAIN, "base", snap("0", "r0"), "u", "m", None).unwrap();
        c.create_branch("dev", MAIN, false).unwrap();
        commit_table(&c, "dev", "a", snap("a", "r1"), "u", "m", None).unwrap();
        commit_table(&c, MAIN, "b", snap("b", "r2"), "u", "m", None).unwrap();
        c.merge("dev", MAIN, false).unwrap();
        let main = c.read_ref(MAIN).unwrap();
        assert!(main.tables.contains_key("a"));
        assert!(main.tables.contains_key("b"));
        assert!(main.tables.contains_key("base"));
        assert!(main.is_merge());
    }

    #[test]
    fn conflicting_merge_rejected() {
        let c = catalog();
        commit_table(&c, MAIN, "t", snap("0", "r0"), "u", "m", None).unwrap();
        c.create_branch("dev", MAIN, false).unwrap();
        commit_table(&c, "dev", "t", snap("a", "r1"), "u", "m", None).unwrap();
        commit_table(&c, MAIN, "t", snap("b", "r2"), "u", "m", None).unwrap();
        let err = c.merge("dev", MAIN, false).unwrap_err();
        assert!(matches!(err, BauplanError::MergeConflict(_)));
    }

    #[test]
    fn merge_is_idempotent() {
        let c = catalog();
        c.create_branch("dev", MAIN, false).unwrap();
        commit_table(&c, "dev", "t", snap("a", "r1"), "u", "m", None).unwrap();
        let m1 = c.merge("dev", MAIN, false).unwrap();
        let m2 = c.merge("dev", MAIN, false).unwrap();
        assert_eq!(m1, m2);
    }

    #[test]
    fn aborted_txn_branch_fork_and_merge_guarded() {
        let c = catalog();
        c.create_txn_branch(MAIN, "r1").unwrap();
        commit_table(&c, "txn/r1", "t", snap("a", "r1"), "u", "m", Some("r1".into())).unwrap();
        c.set_branch_state("txn/r1", BranchState::Aborted).unwrap();
        // fork refused
        let err = c.create_branch("agent", "txn/r1", false).unwrap_err();
        assert!(matches!(err, BauplanError::Visibility(_)));
        // merge refused
        let err = c.merge("txn/r1", MAIN, false).unwrap_err();
        assert!(matches!(err, BauplanError::Visibility(_)));
        // explicit capability opens the escape hatch
        assert!(c.create_branch("agent", "txn/r1", true).is_ok());
    }

    #[test]
    fn log_walks_history() {
        let c = catalog();
        for i in 0..5 {
            commit_table(&c, MAIN, "t", snap(&i.to_string(), "r"), "u", &format!("c{i}"), None)
                .unwrap();
        }
        let log = c.log(MAIN, 10).unwrap();
        assert_eq!(log.len(), 6); // 5 writes + init
        assert_eq!(log[0].message, "c4");
        assert_eq!(log[5].message, "Init");
    }

    #[test]
    fn diff_reports_table_changes() {
        let c = catalog();
        commit_table(&c, MAIN, "keep", snap("k", "r"), "u", "m", None).unwrap();
        commit_table(&c, MAIN, "change", snap("c1", "r"), "u", "m", None).unwrap();
        c.create_branch("dev", MAIN, false).unwrap();
        commit_table(&c, "dev", "change", snap("c2", "r"), "u", "m", None).unwrap();
        commit_table(&c, "dev", "new", snap("n", "r"), "u", "m", None).unwrap();
        let diff = c.diff(MAIN, "dev").unwrap();
        assert_eq!(diff.len(), 2);
        assert!(diff.iter().any(|d| matches!(d, TableDiff::Added(t, _) if t == "new")));
        assert!(diff
            .iter()
            .any(|d| matches!(d, TableDiff::Changed { table, .. } if table == "change")));
    }

    #[test]
    fn tags_are_immutable_refs() {
        let c = catalog();
        commit_table(&c, MAIN, "t", snap("a", "r"), "u", "m", None).unwrap();
        let tagged = c.tag("v1", MAIN).unwrap();
        commit_table(&c, MAIN, "t", snap("b", "r"), "u", "m", None).unwrap();
        assert_eq!(c.resolve("v1").unwrap(), tagged);
        assert_ne!(c.resolve(MAIN).unwrap(), tagged);
        assert!(c.tag("v1", MAIN).is_err()); // no retag
    }

    #[test]
    fn cannot_delete_main() {
        let c = catalog();
        assert!(c.delete_branch(MAIN).is_err());
    }

    #[test]
    fn gc_drops_unreachable_keeps_aborted_roots() {
        let store = Arc::new(ObjectStore::new());
        let c = Catalog::new(store.clone());
        // reachable data on main
        let k1 = store.put(vec![1; 64]);
        commit_table(&c, MAIN, "t", Snapshot::new(vec![k1], "S", "fp", 1, "r1"), "u", "m", None)
            .unwrap();
        // aborted txn branch — must survive GC (triage evidence)
        c.create_txn_branch(MAIN, "r2").unwrap();
        let k2 = store.put(vec![2; 64]);
        commit_table(
            &c,
            "txn/r2",
            "p",
            Snapshot::new(vec![k2.clone()], "S", "fp", 1, "r2"),
            "u",
            "m",
            None,
        )
        .unwrap();
        c.set_branch_state("txn/r2", BranchState::Aborted).unwrap();
        // unreachable: branch deleted after writes
        c.create_branch("tmp", MAIN, false).unwrap();
        let k3 = store.put(vec![3; 64]);
        commit_table(
            &c,
            "tmp",
            "x",
            Snapshot::new(vec![k3.clone()], "S", "fp", 1, "r3"),
            "u",
            "m",
            None,
        )
        .unwrap();
        c.delete_branch("tmp").unwrap();

        let (commits, snaps, objects, bytes) = c.gc().unwrap();
        assert_eq!(commits, 1);
        assert_eq!(snaps, 1);
        assert_eq!(objects, 1);
        assert_eq!(bytes, 64);
        // aborted branch data intact
        assert!(store.get(&k2).is_ok());
        assert!(store.get(&k3).is_err());
        // second gc is a no-op
        assert_eq!(c.gc().unwrap(), (0, 0, 0, 0));
    }

    #[test]
    fn gc_keeps_pinned_snapshots_until_unpinned() {
        let store = Arc::new(ObjectStore::new());
        let c = Catalog::new(store.clone());
        let k = store.put(vec![7; 32]);
        let s = Snapshot::new(vec![k.clone()], "S", "fp", 1, "r1");
        let sid = s.id.clone();
        c.create_branch("tmp", MAIN, false).unwrap();
        commit_table(&c, "tmp", "t", s, "u", "m", None).unwrap();
        c.pin_snapshot(&sid).unwrap();
        c.pin_snapshot(&sid).unwrap(); // refcounted
        c.delete_branch("tmp").unwrap();

        c.gc().unwrap();
        assert!(c.get_snapshot(&sid).is_ok(), "pinned snapshot swept");
        assert!(store.get(&k).is_ok(), "pinned object swept");

        c.unpin_snapshot(&sid);
        c.gc().unwrap();
        assert!(c.get_snapshot(&sid).is_ok(), "second pin ignored");
        assert_eq!(c.pin_count(&sid), 1);

        c.unpin_snapshot(&sid);
        let (_, snaps, objects, _) = c.gc().unwrap();
        assert_eq!((snaps, objects), (1, 1));
        assert!(c.get_snapshot(&sid).is_err());
        // stale pins are refused outright
        assert!(c.pin_snapshot("nope").is_err());
    }

    #[test]
    fn concurrent_writers_serialize() {
        let c = catalog();
        let mut handles = vec![];
        for t in 0..8 {
            let c = c.clone();
            handles.push(std::thread::spawn(move || {
                for i in 0..25 {
                    commit_table(
                        &c,
                        MAIN,
                        &format!("t{t}"),
                        Snapshot::new(vec![format!("o{t}_{i}")], "S", "fp", 1, "r"),
                        "u",
                        "m",
                        None,
                    )
                    .unwrap();
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        // linearizable: history length == total writes + init
        let log = c.log(MAIN, 1000).unwrap();
        assert_eq!(log.len(), 8 * 25 + 1);
        // every thread's final table is present
        let head = c.read_ref(MAIN).unwrap();
        assert_eq!(head.tables.len(), 8);
    }

    #[test]
    fn commit_table_retrying_uncontended_needs_no_retry() {
        let c = catalog();
        let (id, retries) =
            commit_table_retrying(&c, MAIN, "t", snap("a", "r1"), "u", "m", None).unwrap();
        assert_eq!(retries, 0);
        assert_eq!(c.resolve(MAIN).unwrap(), id);
    }

    #[test]
    fn commit_table_retrying_serializes_concurrent_writers() {
        // the scheduler's commit path: many writers, one branch — every
        // commit lands, the table map is complete, history is linear
        let c = catalog();
        let mut handles = vec![];
        for t in 0..8 {
            let c = c.clone();
            handles.push(std::thread::spawn(move || {
                for i in 0..10 {
                    commit_table_retrying(
                        &c,
                        MAIN,
                        &format!("t{t}"),
                        Snapshot::new(vec![format!("o{t}_{i}")], "S", "fp", 1, "r"),
                        "u",
                        "m",
                        None,
                    )
                    .unwrap();
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(c.log(MAIN, 1000).unwrap().len(), 8 * 10 + 1);
        assert_eq!(c.read_ref(MAIN).unwrap().tables.len(), 8);
    }

    #[test]
    fn disjoint_branch_writers_never_conflict() {
        // The OCC claim: commits to disjoint branches validate against
        // heads nobody else moves, so even strict CAS never conflicts.
        let c = catalog();
        for t in 0..4 {
            c.create_branch(&format!("b{t}"), MAIN, false).unwrap();
        }
        let mut handles = vec![];
        for t in 0..4 {
            let c = c.clone();
            handles.push(std::thread::spawn(move || {
                let branch = format!("b{t}");
                let mut head = c.branch_info(&branch).unwrap().head;
                for i in 0..20 {
                    let s = Snapshot::new(vec![format!("o{t}_{i}")], "S", "fp", 1, "r");
                    let req = CommitRequest::new(&branch, "t", s).expected_head(&head);
                    let out = c.commit(req).unwrap();
                    assert_eq!(out.retries, 0);
                    head = out.commit;
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        for t in 0..4 {
            assert_eq!(c.log(&format!("b{t}"), 100).unwrap().len(), 21);
        }
    }

    #[test]
    fn same_branch_race_has_one_winner_per_round() {
        // N writers race strict-CAS rounds from the same observed head:
        // exactly one lands per round, the losers' conflicts carry the
        // live head, and informed retry converges in at most N rounds.
        let c = catalog();
        let n = 4usize;
        let barrier = Arc::new(std::sync::Barrier::new(n));
        let mut handles = vec![];
        for t in 0..n {
            let c = c.clone();
            let barrier = barrier.clone();
            handles.push(std::thread::spawn(move || {
                let mut head = c.branch_info(MAIN).unwrap().head;
                let mut rounds = 0u64;
                barrier.wait();
                loop {
                    rounds += 1;
                    let s = Snapshot::new(vec![format!("o{t}")], "S", "fp", 1, "r");
                    let req =
                        CommitRequest::new(MAIN, &format!("t{t}"), s).expected_head(&head);
                    match c.commit(req) {
                        Ok(_) => return rounds,
                        Err(BauplanError::CasConflict { found, .. }) => {
                            assert_ne!(found, head, "a conflict must carry a moved head");
                            head = found; // informed retry: no extra read
                        }
                        Err(e) => panic!("unexpected error: {e}"),
                    }
                }
            }));
        }
        for h in handles {
            let rounds = h.join().unwrap();
            assert!(rounds <= n as u64, "informed retry took {rounds} > {n} rounds");
        }
        // every writer landed exactly once: linear history, complete map
        assert_eq!(c.log(MAIN, 100).unwrap().len(), n + 1);
        assert_eq!(c.read_ref(MAIN).unwrap().tables.len(), n);
    }

    #[test]
    fn bounded_rebase_gives_up_with_the_live_head() {
        let c = catalog();
        let head0 = c.resolve(MAIN).unwrap();
        commit_table(&c, MAIN, "t", snap("a", "r1"), "u", "m", None).unwrap();
        // pinned on a stale head with zero rebase rounds allowed: the
        // conflict must surface, carrying the head that beat us
        let req = CommitRequest::new(MAIN, "t", snap("b", "r2"))
            .expected_head(&head0)
            .retry(RetryPolicy::Rebase { max_rounds: Some(0) });
        match c.commit(req).unwrap_err() {
            BauplanError::CasConflict { reference, expected, found } => {
                assert_eq!(reference, MAIN);
                assert_eq!(expected, head0);
                assert_eq!(found, c.resolve(MAIN).unwrap());
            }
            e => panic!("unexpected error: {e}"),
        }
        // and with a round budget, the same request rebases and lands
        let req = CommitRequest::new(MAIN, "t", snap("b", "r2"))
            .expected_head(&head0)
            .retry(RetryPolicy::Rebase { max_rounds: Some(2) });
        let out = c.commit(req).unwrap();
        assert_eq!(out.retries, 1);
        assert_eq!(c.resolve(MAIN).unwrap(), out.commit);
    }

    #[test]
    fn delete_table_rebases_like_a_commit() {
        let c = catalog();
        commit_table(&c, MAIN, "t", snap("a", "r1"), "u", "m", None).unwrap();
        commit_table(&c, MAIN, "keep", snap("k", "r1"), "u", "m", None).unwrap();
        c.delete_table(MAIN, "t", "u", None).unwrap();
        let head = c.read_ref(MAIN).unwrap();
        assert!(!head.tables.contains_key("t"));
        assert!(head.tables.contains_key("keep"));
        let err = c.delete_table(MAIN, "t", "u", None).unwrap_err();
        assert!(matches!(err, BauplanError::TableNotFound(_)));
    }

    #[test]
    fn run_records_store_and_list() {
        let c = catalog();
        assert!(c.get_run_record("run_x").is_none());
        c.put_run_record("run_b", Json::str("second")).unwrap();
        c.put_run_record("run_a", Json::str("first")).unwrap();
        assert_eq!(c.get_run_record("run_a").unwrap(), Json::str("first"));
        let all = c.run_records();
        assert_eq!(all.len(), 2);
        assert_eq!(all[0].0, "run_a"); // sorted by run id
        // overwrite is allowed (idempotent re-put)
        c.put_run_record("run_a", Json::str("replaced")).unwrap();
        assert_eq!(c.get_run_record("run_a").unwrap(), Json::str("replaced"));
    }

    #[test]
    fn run_traces_store_list_and_survive_recovery() {
        let c = catalog();
        assert!(c.get_run_trace("run_x").is_none());
        let trace = Json::parse(r#"{"trace_id":"trace_1","spans":[]}"#).unwrap();
        c.put_run_trace("run_b", trace.clone()).unwrap();
        c.put_run_trace("run_a", Json::str("first")).unwrap();
        assert_eq!(c.get_run_trace("run_b").unwrap(), trace);
        let all = c.run_traces();
        assert_eq!(all.len(), 2);
        assert_eq!(all[0].0, "run_a"); // sorted by run id

        // journaled like run records: replay + checkpoint both carry it
        let dir = std::env::temp_dir().join(format!("bpl_rtrace_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let d = Catalog::recover(&dir).unwrap();
        d.put_run_trace("run_j", trace.clone()).unwrap();
        let d2 = Catalog::recover(&dir).unwrap(); // journal replay
        assert_eq!(d2.get_run_trace("run_j").unwrap(), trace);
        d2.checkpoint().unwrap();
        d2.put_run_trace("run_k", Json::str("post-ckpt")).unwrap();
        d2.checkpoint().unwrap(); // delta path must carry traces too
        let d3 = Catalog::recover(&dir).unwrap();
        assert_eq!(d3.get_run_trace("run_j").unwrap(), trace);
        assert_eq!(d3.get_run_trace("run_k").unwrap(), Json::str("post-ckpt"));
        d3.compact().unwrap(); // base export must carry traces too
        let d4 = Catalog::recover(&dir).unwrap();
        assert_eq!(d4.get_run_trace("run_j").unwrap(), trace);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn journal_append_failure_blocks_the_write() {
        // The write-ahead discipline: if the journal cannot take the
        // record, the in-memory mutation must not become visible.
        let dir = std::env::temp_dir().join(format!("bpl_walfail_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let c = Catalog::recover(&dir).unwrap();
        commit_table(&c, MAIN, "t", snap("ok", "r"), "u", "m", None).unwrap();
        let head_before = c.resolve(MAIN).unwrap();
        let (commits_before, ..) = c.sizes();

        c.journal_inject_fail_after(0);
        let err = commit_table(&c, MAIN, "t", snap("doomed", "r"), "u", "m", None);
        assert!(err.is_err());
        assert_eq!(c.resolve(MAIN).unwrap(), head_before);
        assert_eq!(c.sizes().0, commits_before);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn failed_group_sync_poisons_the_catalog() {
        // If the group-commit leader's fsync fails AFTER the mutation was
        // applied and published, the journal cannot reproduce what readers
        // already saw: the caller must get an error, the catalog must
        // refuse every further mutation, and recovery must reopen cleanly.
        let dir = std::env::temp_dir().join(format!("bpl_poison_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let c = Catalog::recover(&dir).unwrap(); // default = GroupCommit
        commit_table(&c, MAIN, "t", snap("ok", "r"), "u", "m", None).unwrap();
        assert!(!c.is_poisoned());

        c.debug_fail_next_group_sync();
        let err = commit_table(&c, MAIN, "t", snap("unsynced", "r"), "u", "m", None)
            .unwrap_err();
        assert!(matches!(err, BauplanError::Io(_) | BauplanError::Poisoned(_)), "{err}");
        assert!(c.is_poisoned(), "a failed durability wait must poison the catalog");

        // the poisoning left a post-mortem: a flight dump under
        // <lake>/flight/ whose last spans include the failure
        let dumps: Vec<_> = std::fs::read_dir(dir.join(crate::trace::FLIGHT_DIR))
            .expect("flight dir exists after poisoning")
            .collect();
        assert!(!dumps.is_empty(), "poisoning must dump the flight ring");

        // every further mutation is refused before touching the journal
        let err = commit_table(&c, MAIN, "t", snap("after", "r"), "u", "m", None).unwrap_err();
        assert!(matches!(err, BauplanError::Poisoned(_)), "{err}");
        let err = c.create_branch("dev", MAIN, false).unwrap_err();
        assert!(matches!(err, BauplanError::Poisoned(_)), "{err}");

        // reopening the lake recovers: un-poisoned, and the acknowledged
        // first commit is there
        drop(c);
        let c2 = Catalog::recover(&dir).unwrap();
        assert!(!c2.is_poisoned());
        let head = c2.read_ref(MAIN).unwrap();
        assert!(head.tables.contains_key("t"));
        commit_table(&c2, MAIN, "t2", snap("fresh", "r"), "u", "m", None).unwrap();
        let _ = std::fs::remove_dir_all(&dir);
    }
}
