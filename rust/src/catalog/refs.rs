//! Refs: branches (movable), tags (immutable), and transactional-branch
//! lifecycle metadata.
//!
//! The branch state machine is the API-level encoding of the lesson from
//! the paper's Alloy counterexample: a *transactional* branch is not just
//! a branch — it has a lifecycle (`Open -> Merged | Aborted`), and aborted
//! branches get stricter visibility (readable for triage, but not
//! forkable/mergeable without an explicit capability).

use crate::catalog::commit::CommitId;

/// A ref name: `main`, `feature/x`, `txn/run_...`, or a tag name.
pub type RefName = String;

/// Lifecycle of a branch.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum BranchState {
    /// Normal branch, or a transactional branch whose run is in flight.
    Open,
    /// Transactional branch successfully merged back (kept briefly for
    /// bookkeeping; deleted by the protocol's final step).
    Merged,
    /// Transactional branch whose run failed — retained for triage, with
    /// restricted visibility (the Fig. 4 guardrail).
    Aborted,
}

/// Everything the catalog knows about one branch.
#[derive(Debug, Clone, PartialEq)]
pub struct BranchInfo {
    /// Branch name (`main`, `feature/x`, `txn/<run_id>`, ...).
    pub name: RefName,
    /// Commit the branch currently points at.
    pub head: CommitId,
    /// Lifecycle state (always `Open` for normal branches).
    pub state: BranchState,
    /// True for `txn/...` branches created by the run engine.
    pub transactional: bool,
    /// The run that owns a transactional branch.
    pub owner_run: Option<String>,
}

impl BranchInfo {
    /// A plain user branch at `head`.
    pub fn normal(name: &str, head: CommitId) -> BranchInfo {
        BranchInfo {
            name: name.into(),
            head,
            state: BranchState::Open,
            transactional: false,
            owner_run: None,
        }
    }

    /// A transactional branch owned by `run_id`, starting `Open`.
    pub fn transactional(name: &str, head: CommitId, run_id: &str) -> BranchInfo {
        BranchInfo {
            name: name.into(),
            head,
            state: BranchState::Open,
            transactional: true,
            owner_run: Some(run_id.into()),
        }
    }

    /// May this branch be used as the *source* of a fork or merge without
    /// the `allow_aborted` capability?
    pub fn freely_visible(&self) -> bool {
        !(self.transactional && self.state == BranchState::Aborted)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn aborted_txn_branches_are_restricted() {
        let mut b = BranchInfo::transactional("txn/r1", "c0".into(), "r1");
        assert!(b.freely_visible());
        b.state = BranchState::Aborted;
        assert!(!b.freely_visible());
    }

    #[test]
    fn aborted_normal_branch_stays_visible() {
        // Only *transactional* branches get the guardrail: a user branch
        // someone abandons is still ordinary Git-for-data.
        let mut b = BranchInfo::normal("feature/x", "c0".into());
        b.state = BranchState::Aborted; // not reachable via public API, but:
        assert!(b.freely_visible());
    }
}
