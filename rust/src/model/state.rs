//! Model state + transition relation.
//!
//! Everything is small integers so states hash fast and the BFS frontier
//! stays compact: tables are `u8` indices into the (shared) plan,
//! snapshots are `(run, step)` pairs — which is precisely the information
//! the consistency predicate needs.

use std::collections::BTreeMap;

/// A snapshot identity: which run wrote it, at which plan step.
pub type Snap = (u8, u8);

/// A model commit: visible table map + parent index. (We keep the full
/// map per commit — scope-bounded, so memory is irrelevant — which makes
/// LCA/merge trivial.)
#[derive(Debug, Clone, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct MCommit {
    pub tables: BTreeMap<u8, Snap>,
    pub parent: Option<u8>,
}

/// Branch kinds in the model.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub enum BranchKind {
    Main,
    /// Transactional branch owned by run `.0`.
    Txn(u8),
    /// A branch an agent forked (the Fig. 4 actor).
    Agent,
}

/// Lifecycle mirror of the real catalog's `BranchState`.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub enum BranchPhase {
    Open,
    Aborted,
    Deleted,
}

#[derive(Debug, Clone, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct MBranch {
    pub kind: BranchKind,
    pub head: u8,
    pub phase: BranchPhase,
}

/// Run lifecycle.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub enum RunPhase {
    Running,
    Published,
    Failed,
}

#[derive(Debug, Clone, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct MRun {
    /// Branch the run executes on (txn branch if transactional).
    pub exec_branch: u8,
    /// Target branch outputs publish to (always main here).
    pub target: u8,
    /// Next plan step to execute.
    pub idx: u8,
    pub phase: RunPhase,
    pub transactional: bool,
}

/// One transition, kept for trace reporting.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum Op {
    BeginRun { run: u8, transactional: bool },
    StepRun { run: u8, table: u8 },
    FailRun { run: u8 },
    PublishRun { run: u8 },
    /// Agent forks a branch from `from` (the counterexample move).
    AgentFork { from: u8 },
    /// Merge branch `src` into main.
    MergeToMain { src: u8 },
}

/// Full model state.
#[derive(Debug, Clone, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct ModelState {
    pub commits: Vec<MCommit>,
    pub branches: Vec<MBranch>,
    pub runs: Vec<MRun>,
}

impl ModelState {
    /// Init: one empty root commit, one main branch (the Alloy model's
    /// `Init` + `Main`).
    pub fn init() -> ModelState {
        ModelState {
            commits: vec![MCommit { tables: BTreeMap::new(), parent: None }],
            branches: vec![MBranch {
                kind: BranchKind::Main,
                head: 0,
                phase: BranchPhase::Open,
            }],
            runs: vec![],
        }
    }

    pub fn main(&self) -> &MBranch {
        &self.branches[0]
    }

    fn head_tables(&self, branch: u8) -> &BTreeMap<u8, Snap> {
        &self.commits[self.branches[branch as usize].head as usize].tables
    }

    /// `createTable` (Listing 8): fresh commit with `table -> snap`,
    /// parent = previous head, advance the branch.
    fn create_table(&mut self, branch: u8, table: u8, snap: Snap) {
        let head = self.branches[branch as usize].head;
        let mut tables = self.commits[head as usize].tables.clone();
        tables.insert(table, snap);
        self.commits.push(MCommit { tables, parent: Some(head) });
        self.branches[branch as usize].head = (self.commits.len() - 1) as u8;
    }

    /// Tables changed on `src` since it forked off the commit `base`.
    fn changes_since(&self, src_head: u8, base: u8) -> BTreeMap<u8, Snap> {
        let base_tables = &self.commits[base as usize].tables;
        self.commits[src_head as usize]
            .tables
            .iter()
            .filter(|(t, s)| base_tables.get(t) != Some(s))
            .map(|(t, s)| (*t, *s))
            .collect()
    }

    /// Lowest common ancestor of two commits (walk parents; the model's
    /// graphs are tiny).
    fn lca(&self, a: u8, b: u8) -> u8 {
        let mut anc = std::collections::BTreeSet::new();
        let mut cur = Some(a);
        while let Some(c) = cur {
            anc.insert(c);
            cur = self.commits[c as usize].parent;
        }
        let mut cur = Some(b);
        while let Some(c) = cur {
            if anc.contains(&c) {
                return c;
            }
            cur = self.commits[c as usize].parent;
        }
        0
    }

    /// Squash-merge `src` into main: apply src's changes since the LCA as
    /// one commit (the model-level mirror of the catalog's merge).
    fn merge_into_main(&mut self, src: u8) {
        let main_head = self.branches[0].head;
        let src_head = self.branches[src as usize].head;
        let base = self.lca(main_head, src_head);
        let changes = self.changes_since(src_head, base);
        if changes.is_empty() {
            return;
        }
        let mut tables = self.commits[main_head as usize].tables.clone();
        tables.extend(changes);
        self.commits.push(MCommit { tables, parent: Some(main_head) });
        self.branches[0].head = (self.commits.len() - 1) as u8;
    }

    /// THE assertion (Fig. 3's global consistency): all plan tables on
    /// main written by one run, or no plan table written at all.
    pub fn main_consistent(&self, plan_len: u8) -> bool {
        let tables = self.head_tables(0);
        let mut writers: Vec<u8> = (0..plan_len)
            .filter_map(|t| tables.get(&t).map(|(r, _)| *r))
            .collect();
        if tables.keys().any(|t| *t >= plan_len) {
            // shouldn't happen: runs only write plan tables
            return false;
        }
        if writers.is_empty() {
            return true;
        }
        if writers.len() != plan_len as usize {
            return false; // partial prefix visible
        }
        writers.dedup();
        writers.len() == 1
    }

    /// Enumerate successor states under the scenario's enabled moves.
    pub fn successors(&self, sc: &super::checker::Scenario) -> Vec<(Op, ModelState)> {
        let mut out = Vec::new();

        // BeginRun — bounded by scenario.max_runs.
        if (self.runs.len() as u8) < sc.max_runs {
            let run_id = self.runs.len() as u8;
            let transactional = sc.transactional;
            let mut s = self.clone();
            let exec_branch = if transactional {
                s.branches.push(MBranch {
                    kind: BranchKind::Txn(run_id),
                    head: s.branches[0].head,
                    phase: BranchPhase::Open,
                });
                (s.branches.len() - 1) as u8
            } else {
                0 // direct write on main
            };
            s.runs.push(MRun {
                exec_branch,
                target: 0,
                idx: 0,
                phase: RunPhase::Running,
                transactional,
            });
            out.push((Op::BeginRun { run: run_id, transactional }, s));
        }

        for (i, run) in self.runs.iter().enumerate() {
            let run_id = i as u8;
            if run.phase != RunPhase::Running {
                continue;
            }
            // StepRun
            if run.idx < sc.plan_len {
                let mut s = self.clone();
                let table = run.idx;
                s.create_table(run.exec_branch, table, (run_id, table));
                s.runs[i].idx += 1;
                out.push((Op::StepRun { run: run_id, table }, s));
            }
            // FailRun — only meaningful after at least one step (a crash
            // before any write leaves no trace).
            if run.idx > 0 && run.idx < sc.plan_len {
                let mut s = self.clone();
                s.runs[i].phase = RunPhase::Failed;
                if run.transactional {
                    s.branches[run.exec_branch as usize].phase = BranchPhase::Aborted;
                }
                out.push((Op::FailRun { run: run_id }, s));
            }
            // PublishRun — all steps done.
            if run.idx == sc.plan_len {
                let mut s = self.clone();
                if run.transactional {
                    s.merge_into_main(run.exec_branch);
                    s.branches[run.exec_branch as usize].phase = BranchPhase::Deleted;
                }
                s.runs[i].phase = RunPhase::Published;
                out.push((Op::PublishRun { run: run_id }, s));
            }
        }

        // Agent moves (the Fig. 4 actor).
        if sc.agents {
            let has_agent = self
                .branches
                .iter()
                .any(|b| b.kind == BranchKind::Agent);
            if !has_agent {
                for (bi, b) in self.branches.iter().enumerate() {
                    let forkable = match (b.kind, b.phase) {
                        // In-flight txn branches are internal to their run
                        // and invisible to other actors; only after a
                        // failure does the branch become reachable "for
                        // debugging and inspection" (§3.3) — which is
                        // precisely what the counterexample exploits.
                        (BranchKind::Txn(_), BranchPhase::Open) => false,
                        (_, BranchPhase::Open) => true,
                        // The guardrail: aborted txn branches are not
                        // freely visible as fork sources.
                        (_, BranchPhase::Aborted) => !sc.guardrail,
                        (_, BranchPhase::Deleted) => false,
                    };
                    if forkable {
                        let mut s = self.clone();
                        s.branches.push(MBranch {
                            kind: BranchKind::Agent,
                            head: b.head,
                            phase: BranchPhase::Open,
                        });
                        out.push((Op::AgentFork { from: bi as u8 }, s));
                    }
                }
            }
            // Agent merges its branch into main.
            for (bi, b) in self.branches.iter().enumerate() {
                if b.kind == BranchKind::Agent && b.phase == BranchPhase::Open {
                    let mut s = self.clone();
                    s.merge_into_main(bi as u8);
                    s.branches[bi].phase = BranchPhase::Deleted;
                    out.push((Op::MergeToMain { src: bi as u8 }, s));
                }
            }
        }

        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::checker::Scenario;

    #[test]
    fn init_is_consistent() {
        assert!(ModelState::init().main_consistent(3));
    }

    #[test]
    fn create_table_advances_head() {
        let mut s = ModelState::init();
        s.create_table(0, 0, (0, 0));
        assert_eq!(s.branches[0].head, 1);
        assert_eq!(s.commits[1].tables[&0], (0, 0));
        assert_eq!(s.commits[1].parent, Some(0));
    }

    #[test]
    fn partial_direct_write_is_inconsistent() {
        let mut s = ModelState::init();
        s.create_table(0, 0, (0, 0)); // run 0 writes table 0 only
        assert!(!s.main_consistent(3));
        s.create_table(0, 1, (0, 1));
        s.create_table(0, 2, (0, 2));
        assert!(s.main_consistent(3)); // complete now
        s.create_table(0, 0, (1, 0)); // run 1 overwrites table 0 only
        assert!(!s.main_consistent(3)); // the Fig. 3 mixed state
    }

    #[test]
    fn txn_run_publish_is_atomic() {
        let sc = Scenario::paper_protocol();
        let s0 = ModelState::init();
        // begin
        let (_, s1) = s0
            .successors(&sc)
            .into_iter()
            .find(|(op, _)| matches!(op, Op::BeginRun { .. }))
            .unwrap();
        // three steps
        let mut s = s1;
        for _ in 0..3 {
            assert!(s.main_consistent(3)); // main untouched mid-run
            let next = s
                .successors(&sc)
                .into_iter()
                .find(|(op, _)| matches!(op, Op::StepRun { .. }))
                .unwrap()
                .1;
            s = next;
        }
        // publish
        let s = s
            .successors(&sc)
            .into_iter()
            .find(|(op, _)| matches!(op, Op::PublishRun { .. }))
            .unwrap()
            .1;
        assert!(s.main_consistent(3));
        assert_eq!(s.head_tables(0).len(), 3);
    }
}
