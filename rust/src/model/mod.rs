//! Bounded model checker over the Git-for-data core (paper §4).
//!
//! The paper formalizes commits/branches/runs in Alloy and small-scope
//! checks them. We reproduce the same model as an explicit-state bounded
//! BFS — the rust analogue of Alloy's small-scope analysis — with the
//! same signature:
//!
//! - a *commit* maps tables to snapshots and has a parent (Listing 7);
//! - the only mutating op is `createTable` (Listing 8): fresh snapshot,
//!   fresh commit, advance the branch head;
//! - a *run* is a plan (sequence of tables) executed step-by-step on a
//!   branch (Listing 9), transactionally (on a forked txn branch merged
//!   at the end) or directly on the target.
//!
//! The checked assertion is pipeline atomicity on `main`
//! ([`ModelState::main_consistent`]): since every run in the model
//! executes the same plan, a main state is consistent iff its plan tables
//! were either all written by the *same* run or none written at all —
//! exactly the global-consistency notion of Fig. 3.
//!
//! [`Scenario`] toggles reproduce the paper's findings:
//! - `transactional: false` → the checker finds the Fig. 3 *top* trace
//!   (direct writes + crash ⇒ main holds a mixed state);
//! - `transactional: true, guardrail: false, agents: true` → the Fig. 4
//!   counterexample (fork an *aborted* txn branch, merge to main);
//! - `guardrail: true` → exhaustive search proves (within scope) the
//!   inconsistency is unreachable.

pub mod state;
pub mod checker;

pub use checker::{check, CheckOutcome, Scenario, Trace};
pub use state::{ModelState, Op, RunPhase};
