//! The bounded BFS checker + the paper's scenarios.

use std::collections::{HashSet, VecDeque};

use crate::model::state::{ModelState, Op};
use crate::util::json::Json;

/// Scope + enabled moves — the model-checking "run" configuration.
#[derive(Debug, Clone)]
pub struct Scenario {
    pub name: &'static str,
    /// Tables every run writes, in order (the shared pipeline plan).
    pub plan_len: u8,
    pub max_runs: u8,
    /// Runs use the transactional protocol (vs direct writes).
    pub transactional: bool,
    /// Aborted txn branches are invisible to forks (the fix).
    pub guardrail: bool,
    /// An agent actor may fork branches and merge into main.
    pub agents: bool,
    /// Safety valve on the search.
    pub max_states: usize,
}

impl Scenario {
    /// Fig. 3 top: today's lakehouses — direct writes, crashes possible.
    pub fn direct_writes() -> Scenario {
        Scenario {
            name: "fig3_top_direct_writes",
            plan_len: 3,
            max_runs: 2,
            transactional: false,
            guardrail: false,
            agents: false,
            max_states: 2_000_000,
        }
    }

    /// Fig. 3 bottom: the paper's protocol, no other actors.
    pub fn paper_protocol() -> Scenario {
        Scenario {
            name: "fig3_bottom_transactional",
            plan_len: 3,
            max_runs: 2,
            transactional: true,
            guardrail: true,
            agents: false,
            max_states: 2_000_000,
        }
    }

    /// Fig. 4: transactional runs, but aborted branches stay visible and
    /// an agent is around.
    pub fn counterexample() -> Scenario {
        Scenario {
            name: "fig4_aborted_branch_visible",
            plan_len: 2,
            max_runs: 2,
            transactional: true,
            guardrail: false,
            agents: true,
            max_states: 5_000_000,
        }
    }

    /// Fig. 4 with the visibility guardrail — the proposed fix.
    pub fn counterexample_fixed() -> Scenario {
        Scenario {
            name: "fig4_with_guardrail",
            guardrail: true,
            ..Scenario::counterexample()
        }
    }
}

/// A counterexample trace: the ops from init to the violating state.
#[derive(Debug, Clone)]
pub struct Trace {
    pub ops: Vec<Op>,
    pub violating_state: ModelState,
}

impl Trace {
    /// Human-readable rendering for examples and EXPERIMENTS.md.
    pub fn render(&self) -> String {
        let mut out = String::new();
        for (i, op) in self.ops.iter().enumerate() {
            out.push_str(&format!("  {i:>2}. {op:?}\n"));
        }
        let main_head = self.violating_state.main().head;
        let tables =
            &self.violating_state.commits[main_head as usize].tables;
        out.push_str(&format!("  => main tables: {tables:?} (MIXED WRITERS)\n"));
        out
    }

    /// Machine-readable rendering (canonical JSON): the op list plus the
    /// violating main-table map as `table -> [run, step]`. Consumed by
    /// `bauplan model-check` and the simulator's artifacts.
    pub fn to_json(&self) -> Json {
        let main_head = self.violating_state.main().head;
        let tables = &self.violating_state.commits[main_head as usize].tables;
        Json::obj(vec![
            ("ops", Json::Arr(self.ops.iter().map(|o| o.to_json()).collect())),
            (
                "main_tables",
                Json::Obj(
                    tables
                        .iter()
                        .map(|(t, (run, step))| {
                            (
                                t.to_string(),
                                Json::Arr(vec![
                                    Json::num(*run as f64),
                                    Json::num(*step as f64),
                                ]),
                            )
                        })
                        .collect(),
                ),
            ),
        ])
    }
}

/// Result of exploring a scenario.
#[derive(Debug, Clone)]
pub struct CheckOutcome {
    pub scenario: &'static str,
    pub states_explored: usize,
    pub max_depth_reached: usize,
    pub violation: Option<Trace>,
}

impl CheckOutcome {
    /// Canonical-JSON encoding for tooling (`bauplan model-check`):
    /// `violation` is `null` when the scope was exhausted clean.
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("scenario", Json::str(self.scenario)),
            ("states_explored", Json::num(self.states_explored as f64)),
            ("max_depth_reached", Json::num(self.max_depth_reached as f64)),
            (
                "violation",
                self.violation.as_ref().map(|t| t.to_json()).unwrap_or(Json::Null),
            ),
        ])
    }
}

/// Explore the scenario's state space breadth-first; stop at the first
/// assertion violation (shortest counterexample, like Alloy) or at
/// exhaustion.
pub fn check(sc: &Scenario) -> CheckOutcome {
    let init = ModelState::init();
    let mut seen: HashSet<ModelState> = HashSet::new();
    let mut queue: VecDeque<(ModelState, Vec<Op>)> = VecDeque::new();
    seen.insert(init.clone());
    queue.push_back((init, vec![]));
    let mut explored = 0;
    let mut max_depth = 0;

    while let Some((state, ops)) = queue.pop_front() {
        explored += 1;
        max_depth = max_depth.max(ops.len());
        if explored >= sc.max_states {
            break;
        }
        for (op, next) in state.successors(sc) {
            if seen.contains(&next) {
                continue;
            }
            let mut next_ops = ops.clone();
            next_ops.push(op);
            if !next.main_consistent(sc.plan_len) {
                return CheckOutcome {
                    scenario: sc.name,
                    states_explored: explored,
                    max_depth_reached: next_ops.len(),
                    violation: Some(Trace { ops: next_ops, violating_state: next }),
                };
            }
            seen.insert(next.clone());
            queue.push_back((next, next_ops));
        }
    }

    CheckOutcome {
        scenario: sc.name,
        states_explored: explored,
        max_depth_reached: max_depth,
        violation: None,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig3_top_direct_writes_finds_partial_state() {
        let out = check(&Scenario::direct_writes());
        let t = out.violation.expect("direct writes must violate atomicity");
        // shortest violation: one run writes its first table on main
        assert!(t.ops.len() <= 3, "trace: {}", t.render());
    }

    #[test]
    fn fig3_bottom_protocol_is_safe_without_agents() {
        let out = check(&Scenario::paper_protocol());
        assert!(
            out.violation.is_none(),
            "unexpected violation: {}",
            out.violation.unwrap().render()
        );
        assert!(out.states_explored > 10);
    }

    #[test]
    fn fig4_counterexample_is_found() {
        let out = check(&Scenario::counterexample());
        let t = out.violation.expect("aborted-branch fork must be found");
        // the trace must involve an agent fork + merge
        assert!(t.ops.iter().any(|o| matches!(o, Op::AgentFork { .. })), "trace: {}", t.render());
        assert!(t.ops.iter().any(|o| matches!(o, Op::MergeToMain { .. })), "trace: {}", t.render());
    }

    #[test]
    fn guardrail_closes_the_counterexample() {
        let out = check(&Scenario::counterexample_fixed());
        assert!(
            out.violation.is_none(),
            "guardrail failed: {}",
            out.violation.unwrap().render()
        );
        // and the search actually exhausted the scope, not just bailed
        assert!(out.states_explored < Scenario::counterexample_fixed().max_states);
    }
}
